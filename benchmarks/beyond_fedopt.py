"""Beyond-paper: FedOpt server optimizers vs the paper's plain averaging.

Same fleet/policy as fig17 (Algorithm 2, sync); only the server-side
aggregation rule changes: avg (paper) vs FedAvgM vs FedAdam."""
from benchmarks.common import build_sim, emit_tta, run


def main(rounds=32, seed=0):
    from benchmarks.common import dynamic_target
    results = {}
    for method, lr in (("avg", 1.0), ("avgm", 1.0), ("adam", 0.03)):
        sim = build_sim(table_config=2, policy="time_based", seed=seed)
        sim.server.cfg.server_opt = method
        from repro.core.server_opt import ServerOptimizer
        sim.server._sopt = ServerOptimizer(method, lr=lr)
        sim.server._sopt_state = sim.server._sopt.init(sim.server.params)
        results[method] = run(sim, mode="sync", rounds=rounds)
        print(f"best,beyond_fedopt.{method},{results[method].best_acc:.4f}")
    target = dynamic_target(*results.values(), frac=0.9)
    times = {m: emit_tta(f"beyond_fedopt.{m}", r, target)
             for m, r in results.items()}
    best = min(times, key=times.get)
    print(f"summary,beyond_fedopt,fastest_server_opt,{best}")
    return times


if __name__ == "__main__":
    main()
