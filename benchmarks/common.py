"""Shared harness for the paper-figure benchmarks (Tier-A event sim)."""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core.client import LocalTrainer, SimWorker
from repro.core.cost_model import heterogeneous_profiles, make_stats
from repro.core.events import FLSimulation
from repro.core.server import AggregationServer, ServerConfig
from repro.data.partition import paper_table3, partition_by_batches
from repro.data.synthetic import make_classification_set
from repro.models import build_model
from repro.models.config import ModelConfig

MLP = ModelConfig(name="bench-mlp", family="cnn", num_layers=0, d_model=96,
                  img_hw=28, img_c=1, n_classes=10, remat=False)
CNN_CIFAR = ModelConfig(name="bench-cnn", family="cnn", num_layers=2,
                        d_model=0, img_hw=32, img_c=3,
                        cnn_channels=(16, 32), n_classes=10, remat=False)

_DATA_CACHE: dict = {}


def dataset(kind: str, n: int, seed: int):
    key = (kind, n, seed)
    if key not in _DATA_CACHE:
        _DATA_CACHE[key] = make_classification_set(kind, n, seed=seed)
    return _DATA_CACHE[key]


def build_sim(*, table_config: int, policy: str, mode: str = "sync",
              seed: int = 0, epochs: int = 2, batch_size: int = 128,
              invert_speed_data: bool = False, rmin: float = 2.0,
              rmax: float = 4.0, random_k: int = 5,
              speed_spread: float = 4.0) -> FLSimulation:
    """Fleet per paper Table III config; MNIST-family -> MLP, CIFAR -> CNN."""
    import jax

    kind, batches = paper_table3(table_config)
    n_workers = len(batches)
    imgs, labels = dataset(kind, 16384, seed)
    test_i, test_l = dataset(kind, 1024, seed + 99)
    shards = partition_by_batches(imgs, labels, batches,
                                  batch_size=batch_size, seed=seed)
    model_cfg = MLP if kind == "synmnist" else CNN_CIFAR
    model = build_model(model_cfg)
    # trainer minibatch is fixed at 64; `batch_size` is the paper's shard
    # allocation unit (Tables III/IV count data in batches)
    trainer = LocalTrainer(model, lr=0.05 if kind == "synmnist" else 0.02,
                           batch_size=64)
    profiles = heterogeneous_profiles(
        n_workers, [s[0].shape[0] for s in shards], seed=seed,
        speed_spread=speed_spread)
    if invert_speed_data:
        # data-rich workers are SLOW (fig16 pathology setup)
        order = np.argsort([-p.n_data for p in profiles])
        speeds = sorted([p.speed_factor for p in profiles])
        for rank, i in enumerate(order):
            profiles[i].speed_factor = speed_spread - speeds[rank] + 1.0

    params = model.init(jax.random.key(seed))
    model_bytes = 4 * sum(int(np.prod(l.shape)) for l in
                          jax.tree.leaves(params))
    workers, stats = {}, {}
    for i, (p, (xi, yi)) in enumerate(zip(profiles, shards)):
        workers[i] = SimWorker(i, xi, yi, trainer, p)
        stats[i] = make_stats(p, t_onedata_server=5e-5, server_freq=2.4e9,
                              model_bytes=model_bytes)
    srv = AggregationServer(
        params, stats,
        ServerConfig(policy=policy, mode=mode, epochs_per_round=epochs,
                     rmin_init=rmin, rmax_init=rmax, random_k=random_k),
        seed=seed)
    # t_per_sample calibrated so compute dominates messaging overheads,
    # matching the paper's CNN-on-VM regime (their rounds took minutes)
    return FLSimulation(srv, workers, test_i[:1024], test_l[:1024],
                        t_per_sample_ref=5e-4, model_bytes=model_bytes,
                        round_overhead=0.1, seed=seed)


def run(sim: FLSimulation, *, mode: str, rounds: int = 48,
        merges: int = 320, target: float = np.inf):
    if mode == "async":
        return sim.run_async(max_merges=merges, target_acc=target)
    return sim.run_sync(rounds=rounds, target_acc=target)


def emit_curve(name: str, result, stride: int = 1):
    for r in result.records[::stride]:
        print(f"curve,{name},{r.time:.2f},{r.acc:.4f},{r.n_selected}")


def dynamic_target(*results, frac: float = 0.95) -> float:
    """Common achievable accuracy target: frac x the WORST series' best."""
    return frac * min(r.best_acc for r in results)


def emit_tta(name: str, result, target: float):
    t = result.time_to_accuracy(target)
    print(f"tta,{name},{target},{t:.2f},{result.best_acc:.4f}")
    return t
