"""Fig. 12: Sequential training vs FL with even data distribution.

Paper claim: FL-even reaches a high accuracy BEFORE sequential (parallel
workers), but sequential eventually reaches the better final accuracy."""
from benchmarks.common import build_sim, emit_curve, emit_tta, run

TARGET = 0.8


def main(rounds=48, seed=0):
    from benchmarks.common import dynamic_target
    seq = run(build_sim(table_config=1, policy="sequential", seed=seed),
              mode="sync", rounds=rounds)
    fl = run(build_sim(table_config=2, policy="all", seed=seed),
             mode="sync", rounds=rounds)
    emit_curve("fig12.sequential", seq)
    emit_curve("fig12.fl_even", fl)
    target = dynamic_target(seq, fl, frac=0.9)
    t_seq = emit_tta("fig12.sequential", seq, target)
    t_fl = emit_tta("fig12.fl_even", fl, target)
    print(f"summary,fig12,fl_reaches_{TARGET}_first,"
          f"{t_fl < t_seq},{t_fl:.1f},{t_seq:.1f}")
    return {"t_fl": t_fl, "t_seq": t_seq}


if __name__ == "__main__":
    main()
