"""Fig. 13: Even vs uneven data distribution (paper: similar time to
stable accuracy)."""
from benchmarks.common import build_sim, emit_curve, emit_tta, run

TARGET = 0.75


def main(rounds=48, seed=0):
    from benchmarks.common import dynamic_target
    even = run(build_sim(table_config=2, policy="all", seed=seed),
               mode="sync", rounds=rounds)
    uneven = run(build_sim(table_config=3, policy="all", seed=seed),
                 mode="sync", rounds=rounds)
    emit_curve("fig13.even", even)
    emit_curve("fig13.uneven", uneven)
    target = dynamic_target(even, uneven, frac=0.9)
    te = emit_tta("fig13.even", even, target)
    tu = emit_tta("fig13.uneven", uneven, target)
    ratio = max(te, tu) / max(min(te, tu), 1e-9)
    print(f"summary,fig13,similar_time_ratio,{ratio:.2f}")
    return {"t_even": te, "t_uneven": tu}


if __name__ == "__main__":
    main()
