"""Fig. 14: Random worker selection vs sequential (paper: random reaches the
same accuracy but SLOWER and less stably)."""
import numpy as np

from benchmarks.common import build_sim, emit_curve, emit_tta, run

TARGET = 0.8


def main(rounds=48, seed=0):
    from benchmarks.common import dynamic_target
    seq = run(build_sim(table_config=1, policy="sequential", seed=seed),
              mode="sync", rounds=rounds)
    rnd = run(build_sim(table_config=2, policy="random", seed=seed,
                        random_k=4), mode="sync", rounds=rounds)
    emit_curve("fig14.sequential", seq)
    emit_curve("fig14.random", rnd)
    target = dynamic_target(seq, rnd, frac=0.9)
    t_seq = emit_tta("fig14.sequential", seq, target)
    t_rnd = emit_tta("fig14.random", rnd, target)
    # instability: std of round-over-round accuracy deltas
    acc = np.array([r.acc for r in rnd.records])
    jitter = float(np.std(np.diff(acc)))
    print(f"summary,fig14,random_slower,{t_rnd > t_seq},"
          f"jitter,{jitter:.4f}")
    return {"t_seq": t_seq, "t_rnd": t_rnd}


if __name__ == "__main__":
    main()
