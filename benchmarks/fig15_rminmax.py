"""Fig. 15: R-min/R-max selection (init 5,5-ish) vs sequential.

Paper finding: Algorithm 1 is NOT more time-efficient than sequential --
rmin/rmax diverge quickly in early training, flooding the selection with
slow workers.  We log the policy state per round to show the divergence."""
from benchmarks.common import build_sim, emit_curve, emit_tta, run

TARGET = 0.8


def main(rounds=36, seed=0):
    from benchmarks.common import dynamic_target
    seq = run(build_sim(table_config=1, policy="sequential", seed=seed),
              mode="sync", rounds=rounds)
    sim = build_sim(table_config=2, policy="rmin_rmax", seed=seed,
                    rmin=5, rmax=5)
    res = run(sim, mode="sync", rounds=rounds)
    emit_curve("fig15.sequential", seq)
    emit_curve("fig15.rminmax", res)
    st = sim.server.policy_state
    print(f"policy,fig15,rmin,{st.rmin:.2f},rmax,{st.rmax:.2f}")
    target = dynamic_target(seq, res, frac=0.9)
    t_seq = emit_tta("fig15.sequential", seq, target)
    t_rmm = emit_tta("fig15.rminmax", res, target)
    diverged = st.rmax / max(st.rmin, 1e-9) > 4.0
    print(f"summary,fig15,rminmax_not_faster,{t_rmm >= t_seq},"
          f"diverged,{diverged}")
    return {"t_seq": t_seq, "t_rmm": t_rmm, "rmin": st.rmin, "rmax": st.rmax}


if __name__ == "__main__":
    main()
