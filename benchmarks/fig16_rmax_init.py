"""Fig. 16: R-min/R-max sensitivity to rmax initialisation.

Paper finding: with rmax initialised to 5/6/7 (and data concentrated on
slow workers -- uneven config), accuracy STALLS far below the achievable
level, because only fast data-poor workers ever get selected."""
from benchmarks.common import build_sim, emit_curve, run


def main(rounds=20, seed=0):
    out = {}
    for rmax in (5, 6, 7):
        sim = build_sim(table_config=3, policy="rmin_rmax", seed=seed,
                        rmin=2, rmax=rmax, invert_speed_data=True,
                        speed_spread=8.0)
        res = run(sim, mode="sync", rounds=rounds)
        emit_curve(f"fig16.rmax{rmax}", res, stride=2)
        out[rmax] = res.best_acc
        print(f"best,fig16.rmax{rmax},{res.best_acc:.4f}")
    ref = run(build_sim(table_config=3, policy="all", seed=seed,
                        invert_speed_data=True, speed_spread=8.0),
              mode="sync", rounds=rounds)
    print(f"best,fig16.all_workers,{ref.best_acc:.4f}")
    stalled = all(a < 0.9 * ref.best_acc for a in out.values())
    print(f"summary,fig16,bad_init_stalls_below_achievable,{stalled}")
    return {"rmax_best": out, "ref_best": ref.best_acc}


if __name__ == "__main__":
    main()
