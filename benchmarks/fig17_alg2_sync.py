"""Fig. 17: Algorithm 2 (training-time-based selection, SYNC) vs random vs
sequential.  Paper: Alg. 2 outperforms both in the EARLY phase (only fast
workers selected), sequential wins late (sync waits on stragglers)."""
from benchmarks.common import build_sim, emit_curve, emit_tta, run

TARGET_EARLY = 0.6
TARGET = 0.8


def main(rounds=48, seed=0):
    from benchmarks.common import dynamic_target
    seq = run(build_sim(table_config=1, policy="sequential", seed=seed),
              mode="sync", rounds=rounds)
    rnd = run(build_sim(table_config=2, policy="random", seed=seed,
                        random_k=4), mode="sync", rounds=rounds)
    alg2 = run(build_sim(table_config=2, policy="time_based", seed=seed),
               mode="sync", rounds=rounds)
    emit_curve("fig17.sequential", seq)
    emit_curve("fig17.random", rnd)
    emit_curve("fig17.alg2_sync", alg2)
    early = dynamic_target(seq, rnd, alg2, frac=0.6)
    te = {n: emit_tta(f"fig17.{n}", r, early)
          for n, r in (("sequential", seq), ("random", rnd),
                       ("alg2_sync", alg2))}
    print(f"summary,fig17,alg2_fastest_early,"
          f"{te['alg2_sync'] <= min(te['sequential'], te['random'])}")
    return te


if __name__ == "__main__":
    main()
