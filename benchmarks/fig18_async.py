"""Fig. 18: Algorithm 2 sync vs ASYNC vs sequential -- the paper's headline
results:

  * worker selection cuts time-to-80%-accuracy by ~34% vs sequential;
  * async improves on sync training time by ~64%.

We report the same two ratios for the reproduction fleet."""
from benchmarks.common import build_sim, emit_curve, emit_tta, run

TARGET = 0.8


def main(rounds=48, merges=320, seed=0):
    from benchmarks.common import dynamic_target
    seq = run(build_sim(table_config=1, policy="sequential", seed=seed),
              mode="sync", rounds=rounds, target=0.99)
    sync = run(build_sim(table_config=2, policy="time_based", seed=seed),
               mode="sync", rounds=rounds, target=0.99)
    asyn = run(build_sim(table_config=2, policy="time_based", mode="async",
                         seed=seed), mode="async", merges=merges,
               target=0.99)
    emit_curve("fig18.sequential", seq)
    emit_curve("fig18.alg2_sync", sync)
    emit_curve("fig18.alg2_async", asyn, stride=2)
    target = dynamic_target(seq, sync, asyn)
    t_seq = emit_tta("fig18.sequential", seq, target)
    t_sync = emit_tta("fig18.alg2_sync", sync, target)
    t_asyn = emit_tta("fig18.alg2_async", asyn, target)
    sel_gain = 1.0 - min(t_sync, t_asyn) / t_seq if t_seq > 0 else 0.0
    async_gain = 1.0 - t_asyn / t_sync if t_sync > 0 else 0.0
    print(f"summary,fig18,selection_vs_sequential_gain,{sel_gain:.2%},"
          f"paper,34%")
    print(f"summary,fig18,async_vs_sync_gain,{async_gain:.2%},paper,64%")
    return {"t_seq": t_seq, "t_sync": t_sync, "t_async": t_asyn,
            "selection_gain": sel_gain, "async_gain": async_gain}


if __name__ == "__main__":
    main()
