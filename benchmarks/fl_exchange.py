"""Compressed weight-exchange benchmark -> BENCH_exchange.json (the perf
trajectory for the cross-island collective; run by the `scale` CI job).

Measures, per island count and compression mode (f32 / q8 / topk /
q8_topk), the bytes-on-wire of one exchange round and the wall time of
the jitted mixing collective (`launch/steps.make_fl_aggregate`) on a
mixed-shape, mixed-dtype parameter tree.  Also records the parity of the
Pallas (kernels/quant8, interpret off-TPU) quantised exchange against the
jnp reference -- the acceptance bound is 1e-2 max-abs.

  PYTHONPATH=src python benchmarks/fl_exchange.py          # measure + write
  PYTHONPATH=src python benchmarks/fl_exchange.py --check  # compare-or-commit:
      writes BENCH_exchange.json if missing, else fails (exit 1) when any
      mode got > REGRESSION_FACTOR x slower or puts MORE bytes on the wire
      than committed.  The structural invariants (q8 >= 3.5x smaller than
      f32, q8_topk strictly smaller than q8, parity <= 1e-2) are enforced
      on every run.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                   # noqa: E402
import jax.numpy as jnp                                      # noqa: E402
import numpy as np                                           # noqa: E402

from repro.core import compression as comp                   # noqa: E402
from repro.core import federated as fed                      # noqa: E402
from repro.launch.steps import make_fl_aggregate             # noqa: E402

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_exchange.json")
REGRESSION_FACTOR = 3.0   # fail --check when > 3x slower than committed
MODES = ("f32", "q8", "topk", "q8_topk")
ISLANDS = (2, 4, 8)
K_FRAC = 0.05
ROUNDS = 10
PARITY_BOUND = 1e-2

# wire accounting per mode: q8 rides the sharding-preserving rowwise
# layout (the exchange's actual form); the topk modes are counted in
# wire form (int32 idx + fp32 val, resp. idx + block-padded int8)
_BYTES_MODE = {"f32": "none", "q8": "q8_rowwise", "topk": "topk",
               "q8_topk": "q8_topk"}


def make_tree(P: int, seed: int = 0):
    """Mixed params: 2-D matmul weights, an embedding table, a
    non-block-multiple bias, and a bf16 norm leaf; stacked over P islands
    with small per-island deltas from a shared base."""
    rng = np.random.default_rng(seed)
    one = {
        "embed": jnp.asarray(rng.normal(size=(512, 256)), jnp.float32),
        "w1": jnp.asarray(rng.normal(size=(256, 1024)), jnp.float32),
        "w2": jnp.asarray(rng.normal(size=(1024, 256)), jnp.float32),
        "bias": jnp.asarray(rng.normal(size=(1027,)), jnp.float32),
        "ln": jnp.asarray(rng.normal(size=(256,)), jnp.bfloat16),
    }
    base = fed.stack_islands(one, P)
    stacked = jax.tree.map(
        lambda x: (x.astype(jnp.float32)
                   + jnp.asarray(rng.normal(size=x.shape) * 0.01,
                                 jnp.float32)).astype(x.dtype), base)
    return stacked, base


def wire_bytes(tree, mode: str) -> int:
    return comp.compressed_bytes(tree, mode=_BYTES_MODE[mode],
                                 k_frac=K_FRAC)


def _time_exchange(fn, args) -> float:
    jax.block_until_ready(fn(*args))          # compile + warm
    jax.block_until_ready(fn(*args))
    t0 = time.monotonic()
    for _ in range(ROUNDS):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.monotonic() - t0) / ROUNDS * 1e3   # ms/round


def measure_parity(P: int = 4) -> dict:
    """Fused (Pallas, interpret off-TPU) vs jnp-reference exchange on the
    mixed tree -- the quantisation rounding must agree."""
    stacked, base = make_tree(P, seed=7)
    M = jnp.asarray(fed.selection_mixing(np.full(P, 1.0 / P), np.ones(P)),
                    jnp.float32)
    out = {}
    for mode in ("q8", "q8_topk"):
        ref = fed.fl_aggregate_compressed(stacked, base, M, mode=mode,
                                          k_frac=K_FRAC, impl="ref")
        pal = fed.fl_aggregate_compressed(stacked, base, M, mode=mode,
                                          k_frac=K_FRAC, impl="pallas")
        err = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                        - b.astype(jnp.float32))))
                  for a, b in zip(jax.tree.leaves(ref),
                                  jax.tree.leaves(pal)))
        out[f"{mode}_pallas_vs_ref_max_abs"] = err
    return out


def run_all() -> dict:
    cells = {}
    for P in ISLANDS:
        stacked, base = make_tree(P)
        M = jnp.asarray(fed.selection_mixing(np.full(P, 1.0 / P),
                                             np.ones(P)), jnp.float32)
        f32_bytes = wire_bytes(stacked, "f32")
        for mode in MODES:
            fn = jax.jit(make_fl_aggregate(
                compress=False if mode == "f32" else mode, k_frac=K_FRAC))
            args = (stacked, M) if mode == "f32" else (stacked, base, M)
            ms = _time_exchange(fn, args)
            wb = wire_bytes(stacked, mode)
            cells[f"P{P}_{mode}"] = {
                "islands": P, "mode": mode,
                "wire_mb_per_round": round(wb / 1e6, 4),
                "reduction_vs_f32": round(f32_bytes / wb, 2),
                "exchange_ms": round(ms, 3),
            }
            print(f"[fl_exchange] P={P} {mode:8s} "
                  f"{wb/1e6:8.3f} MB/round ({f32_bytes/wb:5.2f}x vs f32) "
                  f"{ms:7.3f} ms", flush=True)
    parity = measure_parity()
    for k, v in parity.items():
        print(f"[fl_exchange] parity {k} = {v:.3e}")
    n_one = sum(int(np.prod(x.shape)) for x in
                jax.tree.leaves(make_tree(1)[0]))
    return {
        "bench": "fl_exchange",
        "k_frac": K_FRAC,
        "params_per_island": n_one,
        "cells": cells,
        "parity": {k: float(f"{v:.3e}") for k, v in parity.items()},
    }


def check_invariants(result: dict) -> list[str]:
    bad = []
    for P in ISLANDS:
        q8 = result["cells"][f"P{P}_q8"]
        qtk = result["cells"][f"P{P}_q8_topk"]
        if q8["reduction_vs_f32"] < 3.5:
            bad.append(f"P{P}: q8 reduction {q8['reduction_vs_f32']} < 3.5x")
        if not qtk["wire_mb_per_round"] < q8["wire_mb_per_round"]:
            bad.append(f"P{P}: q8_topk bytes not < q8 bytes")
    for k, v in result["parity"].items():
        if v > PARITY_BOUND:
            bad.append(f"parity {k} = {v} > {PARITY_BOUND}")
    return bad


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="compare against committed BENCH_exchange.json "
                         "(write it when missing)")
    ap.add_argument("--out", default=BENCH_PATH)
    args = ap.parse_args(argv)

    result = run_all()
    bad = check_invariants(result)
    if bad:
        for b in bad:
            print(f"[fl_exchange] INVARIANT VIOLATED: {b}")
        return 1

    if args.check and os.path.exists(args.out):
        with open(args.out) as f:
            committed = json.load(f)
        failures = []
        for name, cell in result["cells"].items():
            old = committed.get("cells", {}).get(name)
            if old is None:
                continue
            ok = True
            if cell["wire_mb_per_round"] > old["wire_mb_per_round"] + 1e-9:
                ok = False
                print(f"[fl_exchange] check {name}: wire bytes grew "
                      f"{old['wire_mb_per_round']} -> "
                      f"{cell['wire_mb_per_round']} MB")
            ceil_ms = old["exchange_ms"] * REGRESSION_FACTOR
            if cell["exchange_ms"] > ceil_ms:
                ok = False
                print(f"[fl_exchange] check {name}: {cell['exchange_ms']}ms "
                      f"vs committed {old['exchange_ms']}ms "
                      f"(ceiling {ceil_ms:.3f})")
            if not ok:
                failures.append(name)
        if failures:
            print(f"[fl_exchange] FAIL: regression in {failures}")
            return 1
        print("[fl_exchange] check passed")
        return 0

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[fl_exchange] wrote {os.path.abspath(args.out)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
