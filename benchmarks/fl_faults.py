"""Byzantine-robustness benchmark -> BENCH_faults.json (run by the `scale`
CI job).

Runs the scenario engine under a seeded 20%-Byzantine fault plan
(sign-flip + 10x scale blow-up, core/faults.py) and compares aggregators:

  clean_fedavg      no faults, weighted FedAvg        (the reference)
  attacked_fedavg   faults + weighted FedAvg          (must degrade)
  attacked_trimmed  faults + coordinate trimmed mean  (within ACC_TOL)
  attacked_krum     faults + multi-Krum               (within ACC_TOL)
  attacked_median   faults + coordinate median        (within ACC_TOL)
  attacked_nonfinite  nan/inf spray + plain FedAvg: the sanitization gate
                      alone must keep the published model finite

Invariants (checked on every run, not just --check):
  * every cell's final server params are finite -- no injected NaN/Inf
    ever reaches the published model;
  * each robust aggregator's best accuracy is within ACC_TOL (2 points)
    of the fault-free run;
  * plain FedAvg under attack loses at least DEGRADE_MIN best accuracy
    (if it didn't, the attack would be too weak to certify the defenses).

  PYTHONPATH=src python benchmarks/fl_faults.py          # measure + write
  PYTHONPATH=src python benchmarks/fl_faults.py --check  # compare-or-commit:
      writes BENCH_faults.json if missing, else fails (exit 1) on an
      invariant violation or a wall-time regression > REGRESSION_FACTOR.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.scenarios import ScenarioConfig, ScenarioSim  # noqa: E402

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_faults.json")
REGRESSION_FACTOR = 3.0   # --check fails when > 3x slower than committed
ACC_TOL = 0.02            # robust agg must stay within 2 points of clean
DEGRADE_MIN = 0.10        # plain FedAvg under attack must lose >= this

ROUNDS = 50
BASE = dict(n_workers=200, cohort_size=12, fog_cells=1, participation=0.2,
            samples_per_worker=96, epochs=2, dirichlet_alpha=100.0, seed=3)
ATTACK = dict(byzantine_frac=0.2, byzantine_attacks=("sign_flip", "scale"),
              byzantine_scale=10.0)

CELLS = {
    "clean_fedavg": {},
    "attacked_fedavg": dict(ATTACK),
    "attacked_trimmed": {**ATTACK, "robust_agg": "trimmed_mean",
                         "trim_frac": 0.3},
    "attacked_krum": {**ATTACK, "robust_agg": "krum"},
    "attacked_median": {**ATTACK, "robust_agg": "median"},
    "attacked_nonfinite": {**ATTACK,
                           "byzantine_attacks": ("nan", "inf")},
}


def measure(name: str, knobs: dict) -> dict:
    cfg = ScenarioConfig(**BASE, **knobs)
    sim = ScenarioSim(cfg, pool=2048, eval_n=512)
    t0 = time.monotonic()
    res = sim.run_sync(ROUNDS)
    wall = time.monotonic() - t0
    accs = [r.acc for r in res.records]
    finite = all(bool(np.isfinite(np.asarray(l)).all())
                 for l in jax.tree.leaves(res.final_params))
    return {
        "rounds": ROUNDS,
        "robust_agg": knobs.get("robust_agg", "none"),
        "byzantine_frac": knobs.get("byzantine_frac", 0.0),
        "best_acc": round(res.best_acc, 4),
        "final_acc": round(float(np.mean(accs[-3:])), 4),
        "params_finite": finite,
        "n_quarantined": len(sim.quarantine),
        "wall_s": round(wall, 3),
    }


def run_all() -> dict:
    cells = {}
    for name, knobs in CELLS.items():
        print(f"[fl_faults] measuring {name} ...", flush=True)
        cells[name] = measure(name, knobs)
    return {
        "bench": "fl_faults",
        "scenario": (f"{BASE['n_workers']} workers, cohort "
                     f"{BASE['cohort_size']}, 20% Byzantine "
                     "(sign_flip + 10x scale)"),
        "acc_tol": ACC_TOL,
        "degrade_min": DEGRADE_MIN,
        "cells": cells,
    }


def check_invariants(result: dict) -> list[str]:
    cells = result["cells"]
    clean = cells["clean_fedavg"]["best_acc"]
    failures = []
    for name, cell in cells.items():
        if not cell["params_finite"]:
            failures.append(f"{name}: non-finite server params")
    for name in ("attacked_trimmed", "attacked_krum", "attacked_median"):
        deficit = clean - cells[name]["best_acc"]
        status = "OK" if deficit <= ACC_TOL else "VIOLATED"
        print(f"[fl_faults] {name}: best_acc {cells[name]['best_acc']} "
              f"(clean {clean}, deficit {deficit:.4f} <= {ACC_TOL}) "
              f"{status}")
        if status == "VIOLATED":
            failures.append(f"{name}: deficit {deficit:.4f} > {ACC_TOL}")
    drop = clean - cells["attacked_fedavg"]["best_acc"]
    status = "OK" if drop >= DEGRADE_MIN else "VIOLATED"
    print(f"[fl_faults] attacked_fedavg: best_acc "
          f"{cells['attacked_fedavg']['best_acc']} (degradation "
          f"{drop:.4f} >= {DEGRADE_MIN}) {status}")
    if status == "VIOLATED":
        failures.append(
            f"attacked_fedavg: attack too weak (drop {drop:.4f})")
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="compare against committed BENCH_faults.json "
                         "(write it when missing)")
    ap.add_argument("--out", default=BENCH_PATH)
    args = ap.parse_args(argv)

    result = run_all()
    for name, cell in result["cells"].items():
        print(f"[fl_faults] {name}: best_acc {cell['best_acc']} "
              f"final {cell['final_acc']} finite {cell['params_finite']} "
              f"({cell['wall_s']}s wall)")

    failures = check_invariants(result)
    if failures:
        print(f"[fl_faults] FAIL: invariant violations: {failures}")
        return 1

    if args.check and os.path.exists(args.out):
        with open(args.out) as f:
            committed = json.load(f)
        slow = []
        for name, cell in result["cells"].items():
            old = committed.get("cells", {}).get(name)
            if old is None:
                continue
            ceiling = old["wall_s"] * REGRESSION_FACTOR
            status = "OK" if cell["wall_s"] <= ceiling else "REGRESSED"
            print(f"[fl_faults] check {name}: {cell['wall_s']}s vs "
                  f"committed {old['wall_s']}s (ceiling {ceiling:.2f}s) "
                  f"{status}")
            if status == "REGRESSED":
                slow.append(name)
        if slow:
            print(f"[fl_faults] FAIL: wall-time regression in {slow}")
            return 1
        print("[fl_faults] check passed")
        return 0

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[fl_faults] wrote {os.path.abspath(args.out)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
