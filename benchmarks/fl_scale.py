"""FL simulation throughput benchmark -> BENCH_fl.json (the perf
trajectory for the scenario engine; run by the `scale` CI job).

Measures rounds/sec (sync) and merges/sec (async) of the scenario engine
at 10^3 and 10^5 simulated workers, under the full churn + straggler +
non-IID-drift load.  Timing covers the WHOLE loop: vectorized population
timing, shard synthesis, the vmapped cohort train step, the
edge->fog->cloud fold, and evaluation.

  PYTHONPATH=src python benchmarks/fl_scale.py          # measure + write
  PYTHONPATH=src python benchmarks/fl_scale.py --check  # compare-or-commit:
      writes BENCH_fl.json if missing, else fails (exit 1) when any cell
      regressed below REGRESSION_FACTOR x its committed throughput.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.scenarios import ScenarioConfig, ScenarioSim  # noqa: E402

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_fl.json")
REGRESSION_FACTOR = 3.0   # fail --check when > 3x slower than committed

SYNC_ROUNDS = 5
ASYNC_MERGES = 64


def scenario(n_workers: int) -> ScenarioConfig:
    return ScenarioConfig(
        n_workers=n_workers, cohort_size=16, participation=0.05,
        churn_leave=0.02, churn_join=0.02, straggler_frac=0.05, drift=0.3,
        dirichlet_alpha=0.5, epochs=1, samples_per_worker=64, seed=1)


def measure(n_workers: int) -> dict:
    cfg = scenario(n_workers)
    # warm the jit caches outside the timed region so the numbers track the
    # steady-state loop, not compilation
    ScenarioSim(cfg).run_sync(1)

    t0 = time.monotonic()
    sync = ScenarioSim(cfg).run_sync(SYNC_ROUNDS)
    sync_wall = time.monotonic() - t0

    t0 = time.monotonic()
    asyn = ScenarioSim(cfg).run_async(ASYNC_MERGES)
    async_wall = time.monotonic() - t0

    return {
        f"sync_n{n_workers}": {
            "workers": n_workers, "rounds": SYNC_ROUNDS,
            "wall_s": round(sync_wall, 3),
            "rounds_per_s": round(SYNC_ROUNDS / sync_wall, 3),
            "best_acc": round(sync.best_acc, 4),
        },
        f"async_n{n_workers}": {
            "workers": n_workers, "merges": ASYNC_MERGES,
            "wall_s": round(async_wall, 3),
            "rounds_per_s": round(ASYNC_MERGES / async_wall, 3),
            "best_acc": round(asyn.best_acc, 4),
        },
    }


def run_all() -> dict:
    cells = {}
    for n in (1_000, 100_000):
        print(f"[fl_scale] measuring n_workers={n} ...", flush=True)
        cells.update(measure(n))
    return {
        "bench": "fl_scale",
        "scenario": "churn+stragglers+non-IID drift, 5% participation",
        "cells": cells,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="compare against committed BENCH_fl.json "
                         "(write it when missing)")
    ap.add_argument("--out", default=BENCH_PATH)
    args = ap.parse_args(argv)

    result = run_all()
    for name, cell in result["cells"].items():
        print(f"[fl_scale] {name}: {cell['rounds_per_s']} rounds/s "
              f"({cell['wall_s']}s wall, best_acc {cell['best_acc']})")

    if args.check and os.path.exists(args.out):
        with open(args.out) as f:
            committed = json.load(f)
        failures = []
        for name, cell in result["cells"].items():
            old = committed.get("cells", {}).get(name)
            if old is None:
                continue
            floor = old["rounds_per_s"] / REGRESSION_FACTOR
            status = "OK" if cell["rounds_per_s"] >= floor else "REGRESSED"
            print(f"[fl_scale] check {name}: {cell['rounds_per_s']} vs "
                  f"committed {old['rounds_per_s']} (floor {floor:.3f}) "
                  f"{status}")
            if status == "REGRESSED":
                failures.append(name)
        if failures:
            print(f"[fl_scale] FAIL: throughput regression in {failures}")
            return 1
        print("[fl_scale] check passed")
        return 0

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[fl_scale] wrote {os.path.abspath(args.out)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
