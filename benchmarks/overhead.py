"""Framework-overhead microbenchmarks -- the paper's 'lightweight' claim.

Emits `name,us_per_call,derived` rows: worker selection over large fleets,
aggregation of real-size models, warehouse pointer ops, int8 compression."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, selection
from repro.core.cost_model import WorkerStats
from repro.core.warehouse import DataWarehouse


def _time(fn, n=20, warmup=2):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6  # us


def bench_selection(n_workers: int):
    rng = np.random.default_rng(0)
    stats = {i: WorkerStats(i, float(rng.uniform(1, 10)),
                            float(rng.uniform(0.1, 1)), int(rng.integers(1, 100)))
             for i in range(n_workers)}
    st1 = selection.RMinRMaxState(2, 4)
    st2 = selection.TimeBasedState(T=20.0, r=2)
    us1 = _time(lambda: selection.rmin_rmax_select(stats, st1))
    us2 = _time(lambda: selection.time_based_select(stats, st2))
    print(f"selection.rmin_rmax.{n_workers}w,{us1:.1f},us_per_round")
    print(f"selection.time_based.{n_workers}w,{us2:.1f},us_per_round")


def bench_aggregation(n_params: int, k: int):
    trees = [{"w": jnp.ones((n_params,), jnp.float32) * i}
             for i in range(k)]
    w = np.full(k, 1.0 / k)
    fn = jax.jit(lambda ts: aggregation.weighted_average(ts, w))
    fn(trees)["w"].block_until_ready()
    us = _time(lambda: fn(trees)["w"].block_until_ready(), n=10)
    gbps = n_params * 4 * k / (us / 1e6) / 1e9
    print(f"aggregation.fedavg.{k}x{n_params//1000}k,{us:.1f},{gbps:.2f}GBps")


def bench_kernel_agg(n_params: int, k: int):
    from repro.kernels.fed_agg.ops import fed_agg
    x = jnp.ones((k, n_params), jnp.float32)
    w = jnp.full((k,), 1.0 / k, jnp.float32)
    fed_agg(x, w).block_until_ready()
    us = _time(lambda: fed_agg(x, w).block_until_ready(), n=10)
    print(f"kernel.fed_agg.{k}x{n_params//1000}k,{us:.1f},interpret_mode")


def bench_warehouse():
    wh = DataWarehouse()
    tree = {"w": jnp.ones((250_000,), jnp.float32)}
    us_put = _time(lambda: wh.put(tree), n=20)
    ptr = wh.put(tree)
    us_get = _time(lambda: wh.get(ptr.uid), n=50)
    us_cred = _time(lambda: wh.fetch(wh.issue_credential(ptr.uid)), n=50)
    print(f"warehouse.put.1MB,{us_put:.1f},pointer_store")
    print(f"warehouse.get.1MB,{us_get:.1f},pointer_fetch")
    print(f"warehouse.credential_fetch.1MB,{us_cred:.1f},one_time_token")


def bench_compression():
    from repro.core import compression
    x = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(1 << 20,)),
                          jnp.float32)}
    fn = jax.jit(lambda t: compression.quantize_blockwise(t["w"], block=256))
    jax.block_until_ready(fn(x))
    us = _time(lambda: jax.block_until_ready(fn(x)), n=10)
    ratio = compression.compressed_bytes(x) / (x["w"].size * 4)
    print(f"compression.int8.4MB,{us:.1f},ratio={ratio:.3f}")


def main():
    print("name,us_per_call,derived")
    for n in (100, 1000, 10000):
        bench_selection(n)
    bench_aggregation(1 << 20, 10)
    bench_kernel_agg(1 << 18, 8)
    bench_warehouse()
    bench_compression()


if __name__ == "__main__":
    main()
