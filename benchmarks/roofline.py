"""Roofline table generator: reads artifacts/dryrun/*.json and emits the
EXPERIMENTS.md SSRoofline tables (per arch x shape x mesh: three terms,
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs usefulness ratio, memory fit).

Conventions (see DESIGN.md SS5 + dist/hlo_cost.py):
  * flops/bytes/collective are PER DEVICE from the trip-count-aware HLO
    cost model (XLA's cost_analysis counts scan bodies once -- unusable);
  * MODEL_FLOPS = 6*N*D (train) or 2*N*D (decode/prefill forward), N_active
    for MoE, D = tokens processed per step;
  * hardware: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI per chip.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.dist.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_ART_ROOT = Path(__file__).resolve().parents[1] / "artifacts"
# read the optimized sweep when present, else the baseline sweep
ARTIFACTS = (_ART_ROOT / "dryrun_opt") if (_ART_ROOT / "dryrun_opt").exists() \
    else (_ART_ROOT / "dryrun")

SHAPE_TOKENS = {  # (tokens per step, flops multiplier per param per token)
    "train_4k": (4096 * 256, 6),
    "prefill_32k": (32768 * 32, 2),
    "decode_32k": (1 * 128, 2),
    "long_500k": (1 * 1, 2),
}


def model_flops(rec: dict) -> float:
    toks, mult = SHAPE_TOKENS[rec["shape"]]
    return mult * rec["n_active_params"] * toks


def load_cells(mesh: str = "single", tag: str | None = None):
    rows = []
    suffix = f"__{mesh}" + (f"__{tag}" if tag else "") + ".json"
    for f in sorted(ARTIFACTS.glob(f"*{suffix}")):
        if tag is None and f.name.count("__") != 2:
            continue
        rec = json.loads(f.read_text())
        rows.append(rec)
    return rows


def chips(rec) -> int:
    n = 1
    for v in rec["mesh_shape"].values():
        n *= v
    return n


def cell_row(rec: dict, entry_name: str | None = None) -> dict | None:
    if rec["status"] == "skipped":
        return {"arch": rec["arch"], "shape": rec["shape"],
                "mesh": rec["mesh"], "status": "skipped",
                "reason": rec.get("reason", "")[:60]}
    if rec["status"] != "ok":
        return {"arch": rec["arch"], "shape": rec["shape"],
                "mesh": rec["mesh"], "status": "error"}
    entry_name = entry_name or {
        "train_4k": "train_step", "prefill_32k": "prefill_step",
        "decode_32k": "decode_step", "long_500k": "decode_step",
    }[rec["shape"]]
    e = rec["entries"][entry_name]
    hc = e["hlo_cost"]
    t_c = hc["flops"] / PEAK_FLOPS_BF16
    t_m = hc["hbm_bytes"] / HBM_BW
    t_x = hc["collective_bytes"] / ICI_BW
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
              key=lambda kv: kv[1])[0]
    mf = model_flops(rec)
    n_chips = chips(rec)
    useful = mf / n_chips / max(hc["flops"], 1e-9)
    mem = e.get("memory_analysis", {})
    ld = rec.get("layout_decision") or {}
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "status": "ok", "entry": entry_name,
        "layout": ld.get("layout", ""),
        "layout_fits": ld.get("fits"),
        "layout_headroom_gb": ld.get("headroom_gb"),
        "layout_reason": ld.get("reason", ""),
        "layout_candidates": ld.get("candidates", []),
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "dominant": dom, "bound_s": max(t_c, t_m, t_x),
        "model_flops": mf, "useful_ratio": useful,
        "roofline_fraction": t_c / max(t_c, t_m, t_x) * useful,
        "hbm_gb_per_dev": (mem.get("argument_size_in_bytes", 0)
                           + mem.get("temp_size_in_bytes", 0)) / 1e9,
        "coll_by_op": {k: round(v / 1e9, 2)
                       for k, v in hc["collective_by_op"].items()},
    }


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | dominant "
           "| useful FLOPs | roofline frac | mem GB/dev | layout |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | -- | -- | -- | "
                       f"{r['status']}: {r.get('reason','')} | -- | -- | -- "
                       f"| -- |\n")
            continue
        layout = r.get("layout") or "--"
        if layout != "--" and r.get("layout_fits") is False:
            layout += " (!fit)"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3g} | "
            f"{r['t_memory_s']:.3g} | {r['t_collective_s']:.3g} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {r['hbm_gb_per_dev']:.1f} | "
            f"{layout} |\n")
    return "".join(out)


def main():
    print("name,us_per_call,derived")
    for mesh in ("single", "multi"):
        for rec in load_cells(mesh):
            r = cell_row(rec)
            if r is None:
                continue
            if r["status"] != "ok":
                print(f"roofline.{r['arch']}.{r['shape']}.{mesh},0,"
                      f"{r['status']}")
                continue
            print(f"roofline.{r['arch']}.{r['shape']}.{mesh},"
                  f"{r['bound_s']*1e6:.0f},"
                  f"dom={r['dominant']};useful={r['useful_ratio']:.2f};"
                  f"frac={r['roofline_fraction']:.3f}")


if __name__ == "__main__":
    main()
