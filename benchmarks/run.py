"""Benchmark driver: one bench per paper figure/table + framework overhead
+ the roofline reader.  Prints ``name,us_per_call,derived`` CSV rows plus
per-figure curve/summary rows."""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig12,...,fig18,overhead,roofline")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from benchmarks import (beyond_fedopt, fig12_sequential_vs_fl,
                            fig13_even_vs_uneven, fig14_random_vs_sequential,
                            fig15_rminmax, fig16_rmax_init, fig17_alg2_sync,
                            fig18_async, overhead, roofline)
    benches = {
        "fig12": fig12_sequential_vs_fl.main,
        "fig13": fig13_even_vs_uneven.main,
        "fig14": fig14_random_vs_sequential.main,
        "fig15": fig15_rminmax.main,
        "fig16": fig16_rmax_init.main,
        "fig17": fig17_alg2_sync.main,
        "fig18": fig18_async.main,
        "fedopt": beyond_fedopt.main,
        "overhead": overhead.main,
        "roofline": roofline.main,
    }
    only = set(args.only.split(",")) if args.only else set(benches)
    for name, fn in benches.items():
        if name not in only:
            continue
        print(f"=== {name} ===", flush=True)
        t0 = time.time()
        try:
            fn()
        except FileNotFoundError as e:
            print(f"skip,{name},missing_artifacts,{e}")
        print(f"bench.{name},{(time.time()-t0)*1e6:.0f},wall_us", flush=True)


if __name__ == "__main__":
    main()
