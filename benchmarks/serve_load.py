"""Serving load benchmark -> BENCH_serve.json (the perf trajectory for
the paged serving path; run by the `serve` CI step).

Drives the block-table paged serve loop (and the contiguous baseline)
with the seeded open-loop generator (launch/loadgen.py) at a smoke-scale
target QPS on the granite smoke model, and reports p50/p99 request
latency, time-to-first-token, and output tokens/s.  A shared-prefix
workload exercises prefix sharing; a parity pass replays the same trace
through both cache disciplines on a virtual clock and requires
token-identical outputs.

  PYTHONPATH=src python benchmarks/serve_load.py          # measure + write
  PYTHONPATH=src python benchmarks/serve_load.py --check  # compare-or-commit:
      writes BENCH_serve.json if missing, else fails (exit 1) when any cell
      regressed below REGRESSION_FACTOR x its committed tokens/s or above
      REGRESSION_FACTOR x its committed p99.  Hard invariants (paged ==
      contiguous token streams, p99 bound, tokens/s floor, prefix sharing
      active) are enforced on EVERY run, check or not.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.launch import loadgen  # noqa: E402
from repro.launch.serve_loop import PagedServeLoop, ServeLoop  # noqa: E402
from repro.models import build_model  # noqa: E402

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")
REGRESSION_FACTOR = 3.0   # fail --check when > 3x off the committed cell

ARCH = "granite-20b"
QPS = 12.0
DURATION_S = 3.0
# hard invariants, enforced every run (generous: CI boxes are slow)
P99_BOUND_MS = 20_000.0
TOKENS_PER_S_FLOOR = 5.0

POOL = dict(max_batch=4, num_blocks=48, block_size=8, chunk=32)


def _loops(model, params):
    paged = PagedServeLoop(model, params, **POOL)
    contiguous = ServeLoop(model, params, max_batch=POOL["max_batch"],
                           max_len=POOL["num_blocks"] * POOL["block_size"])
    return paged, contiguous


def _load_cfg(vocab, shared=False):
    return loadgen.LoadConfig(
        qps=QPS, duration_s=DURATION_S, seed=7, vocab_size=vocab,
        prompt_mean=20, prompt_max=80, out_mean=8, out_max=24,
        shared_prefix_frac=0.5 if shared else 0.0, shared_prefix_len=16)


def measure(model, params) -> tuple[dict, dict]:
    vocab = model.cfg.vocab_size
    cells = {}

    # warm the jit caches (prefill buckets + decode) outside timed regions
    warm = loadgen.LoadConfig(qps=50, duration_s=0.2, seed=1,
                              vocab_size=vocab, prompt_mean=20,
                              prompt_max=80)
    for loop in _loops(model, params):
        loadgen.run_trace(loop, loadgen.generate(warm), tick_s=None)

    for name, shared, paged in (("paged_smoke", False, True),
                                ("paged_shared_prefix", True, True),
                                ("contiguous_smoke", False, False)):
        trace = loadgen.generate(_load_cfg(vocab, shared))
        ploop, cloop = _loops(model, params)
        loop = ploop if paged else cloop
        t0 = time.monotonic()
        records = loadgen.run_trace(loop, trace, tick_s=None)
        wall = time.monotonic() - t0
        cell = loadgen.summarize(records, wall)
        cell["qps"] = QPS
        if paged:
            cell["preemptions"] = loop.preemptions
            cell["shared_blocks"] = loop.alloc.stats["shared_blocks"]
            cell["evictions"] = loop.alloc.stats["evictions"]
        cells[name] = cell
        print(f"[serve_load] {name}: p50 {cell['p50_ms']}ms "
              f"p99 {cell['p99_ms']}ms  {cell['tokens_per_s']} tok/s "
              f"({cell['n_requests']} reqs)", flush=True)

    # parity: identical virtual-clock trace through both disciplines
    trace = loadgen.generate(_load_cfg(vocab, shared=True))
    ploop, cloop = _loops(model, params)
    got = loadgen.run_trace(ploop, trace, tick_s=0.01)
    want = loadgen.run_trace(cloop, trace, tick_s=0.01)
    mismatches = sum(g.out != w.out for g, w in zip(got, want))
    parity = {"n_requests": len(trace), "mismatches": mismatches,
              "shared_blocks": ploop.alloc.stats["shared_blocks"]}
    print(f"[serve_load] parity: {mismatches}/{len(trace)} mismatched "
          f"({parity['shared_blocks']} prefix blocks shared)", flush=True)
    return cells, parity


def check_invariants(cells: dict, parity: dict) -> list[str]:
    bad = []
    if parity["mismatches"]:
        bad.append(f"paged/contiguous token streams diverge: "
                   f"{parity['mismatches']}/{parity['n_requests']}")
    if parity["shared_blocks"] == 0:
        bad.append("shared-prefix workload shared no blocks")
    for name in ("paged_smoke", "paged_shared_prefix"):
        c = cells[name]
        if c["p99_ms"] > P99_BOUND_MS:
            bad.append(f"{name}: p99 {c['p99_ms']}ms > {P99_BOUND_MS}ms")
        if c["tokens_per_s"] < TOKENS_PER_S_FLOOR:
            bad.append(f"{name}: {c['tokens_per_s']} tok/s < "
                       f"{TOKENS_PER_S_FLOOR}")
    return bad


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="compare against committed BENCH_serve.json "
                         "(write it when missing)")
    ap.add_argument("--out", default=BENCH_PATH)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(ARCH)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    cells, parity = measure(model, params)

    bad = check_invariants(cells, parity)
    if bad:
        print(f"[serve_load] FAIL invariants: {bad}")
        return 1

    result = {
        "bench": "serve_load",
        "arch": f"{ARCH}-smoke",
        "workload": f"open-loop poisson {QPS} qps x {DURATION_S}s, "
                    "lognormal prompts / geometric outputs",
        "pool": POOL,
        "cells": cells,
        "parity": parity,
    }

    if args.check and os.path.exists(args.out):
        with open(args.out) as f:
            committed = json.load(f)
        failures = []
        for name, cell in cells.items():
            old = committed.get("cells", {}).get(name)
            if old is None:
                continue
            tps_floor = old["tokens_per_s"] / REGRESSION_FACTOR
            p99_ceil = old["p99_ms"] * REGRESSION_FACTOR
            ok = (cell["tokens_per_s"] >= tps_floor
                  and cell["p99_ms"] <= p99_ceil)
            print(f"[serve_load] check {name}: {cell['tokens_per_s']} tok/s "
                  f"(floor {tps_floor:.2f}), p99 {cell['p99_ms']}ms "
                  f"(ceil {p99_ceil:.0f}) {'OK' if ok else 'REGRESSED'}")
            if not ok:
                failures.append(name)
        if failures:
            print(f"[serve_load] FAIL: serving regression in {failures}")
            return 1
        print("[serve_load] check passed")
        return 0

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[serve_load] wrote {os.path.abspath(args.out)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
