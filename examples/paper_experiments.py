"""Reproduce the paper's experiment suite (Figs. 12-18) end to end.

  PYTHONPATH=src python examples/paper_experiments.py            # all
  PYTHONPATH=src python examples/paper_experiments.py fig18      # one

Prints curve CSV + the two headline metrics (worker selection vs
sequential ~34%, async vs sync ~64%)."""
import sys

from benchmarks import run as bench_run


def main():
    only = sys.argv[1] if len(sys.argv) > 1 else \
        "fig12,fig13,fig14,fig15,fig16,fig17,fig18"
    sys.argv = ["paper_experiments", "--only", only]
    bench_run.main()


if __name__ == "__main__":
    main()
