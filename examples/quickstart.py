"""Quickstart: a complete FLight run in ~30 lines.

Five heterogeneous workers federate a classifier on private synMNIST
shards with Algorithm 2 (training-time-based) selection, asynchronously.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.client import LocalTrainer, SimWorker
from repro.core.cost_model import heterogeneous_profiles, make_stats
from repro.core.events import FLSimulation
from repro.core.server import AggregationServer, ServerConfig
from repro.data.partition import partition_by_batches
from repro.data.synthetic import make_classification_set
from repro.models import build_model

# 1. model + private data shards (batches per worker: uneven on purpose)
model = build_model(get_config("flight-cnn-mnist"))
images, labels = make_classification_set("synmnist", 8192, seed=0)
shards = partition_by_batches(images, labels, [4, 2, 2, 1, 1], batch_size=64)

# 2. heterogeneous fleet (speeds 1-4x) + the server's Eq.4 estimates
profiles = heterogeneous_profiles(5, [s[0].shape[0] for s in shards], seed=0)
params = model.init(jax.random.key(0))
model_bytes = 4 * sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
trainer = LocalTrainer(model, lr=0.05, batch_size=64)
workers = {i: SimWorker(i, x, y, trainer, p)
           for i, (p, (x, y)) in enumerate(zip(profiles, shards))}
stats = {i: make_stats(p, t_onedata_server=5e-5, server_freq=2.4e9,
                       model_bytes=model_bytes) for i, p in
         enumerate(profiles)}

# 3. aggregation server: Algorithm 2 selection, async staleness-aware merge
server = AggregationServer(params, stats, ServerConfig(
    policy="time_based", mode="async", epochs_per_round=4))

# 4. run: the engine simulates wall-clock from the profiles while the
#    workers really train on their shards
test_i, test_l = make_classification_set("synmnist", 1024, seed=9)
sim = FLSimulation(server, workers, test_i, test_l, t_per_sample_ref=5e-5,
                   model_bytes=model_bytes, seed=0)
result = sim.run_async(max_merges=80)

for r in result.records[::8]:
    print(f"t={r.time:7.1f}s  acc={r.acc:.3f}  merges={r.round}")
print(f"\nbest accuracy {result.best_acc:.3f}; "
      f"time to 80%: {result.time_to_accuracy(0.8):.1f}s simulated")
