"""Batched serving example: prefill a batch of prompts and decode greedily
with the KV/state-cache serve path (works for every assigned architecture).

  PYTHONPATH=src python examples/serve_batched.py --arch falcon-mamba-7b
  PYTHONPATH=src python examples/serve_batched.py --arch mixtral-8x22b
"""
import sys

from repro.launch import serve


def main():
    argv = sys.argv[1:] or ["--arch", "falcon-mamba-7b", "--batch", "4",
                            "--prompt-len", "64", "--gen", "24"]
    serve.main(argv)


if __name__ == "__main__":
    main()
