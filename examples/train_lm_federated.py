"""End-to-end driver: federated pretraining of a ~100M-param LM.

Two FL islands train a granite-family decoder on disjoint token streams,
exchanging weights every 5 steps through the Tier-B mixing collective,
with checkpoints + straggler-aware selection -- the production train loop
at CPU-runnable scale.

Defaults are CPU-friendly (~10M params, 60 steps, minutes); pass
--hundred-m for the full ~100M/300-step run (same code path, longer).

  PYTHONPATH=src python examples/train_lm_federated.py
  PYTHONPATH=src python examples/train_lm_federated.py --hundred-m
"""
import argparse
import dataclasses
import sys

import repro.configs.granite_20b as granite
from repro.launch import train as train_launcher
from repro.configs import get_smoke_config


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.hundred_m:
        # ~100M decoder: 12L x 768 x 12H, 32k vocab
        cfg = dataclasses.replace(
            get_smoke_config("granite-20b"),
            num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
            head_dim=64, d_ff=3072, vocab_size=32_768, remat=True)
        steps = args.steps or 300
        batch, seq = 8, 256
    else:
        cfg = dataclasses.replace(
            get_smoke_config("granite-20b"),
            num_layers=6, d_model=256, num_heads=8, num_kv_heads=2,
            head_dim=32, d_ff=1024, vocab_size=8_192)
        steps = args.steps or 60
        batch, seq = 8, 128

    # register the custom config under a temp name by monkeypatching the
    # launcher's config lookup (the launcher otherwise uses the registry)
    import repro.launch.train as T
    orig = T.get_smoke_config
    T.get_smoke_config = lambda name: cfg
    try:
        argv = ["--arch", "custom-lm", "--smoke", "--steps", str(steps),
                "--islands", "2", "--local-steps", "5",
                "--batch", str(batch), "--seq", str(seq),
                "--ckpt-dir", "/tmp/flight_lm_ckpt", "--ckpt-every", "25"]
        if args.resume:
            argv.append("--resume")
        T.main(argv)
    finally:
        T.get_smoke_config = orig


if __name__ == "__main__":
    main()
