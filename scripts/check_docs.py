"""Docs lint: relative links must resolve, dist modules must be documented.

Checks (both are cheap, pure-stdlib, run in CI's docs job and in
tests/test_docs.py):

  1. every relative markdown link in README.md / EXPERIMENTS.md /
     ROADMAP.md points at a file or directory that exists (http(s) links
     and #anchors within the same file are skipped);
  2. every python module under src/repro/dist/ has a module docstring.

Exit code 0 when clean, 1 with one line per violation otherwise.

  python scripts/check_docs.py
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DOCS = ("README.md", "EXPERIMENTS.md", "ROADMAP.md")
DOCSTRING_ROOTS = ("src/repro/dist",)

# [text](target) -- but not images' inner () and not footnote refs
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check_links(doc: Path) -> list[str]:
    problems = []
    text = doc.read_text()
    # strip fenced code blocks: links in shell examples are not links
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path = target.split("#", 1)[0]
        if not path:          # pure #anchor into the same file
            continue
        if not (doc.parent / path).exists():
            problems.append(f"{doc.name}: broken relative link -> {target}")
    return problems


def check_docstrings(root: Path) -> list[str]:
    problems = []
    for py in sorted(root.rglob("*.py")):
        tree = ast.parse(py.read_text())
        if ast.get_docstring(tree) is None:
            problems.append(
                f"{py.relative_to(ROOT)}: missing module docstring")
    return problems


def main() -> int:
    problems = []
    for name in DOCS:
        doc = ROOT / name
        if doc.exists():
            problems += check_links(doc)
        else:
            problems.append(f"{name}: file missing")
    for rel in DOCSTRING_ROOTS:
        problems += check_docstrings(ROOT / rel)
    for p in problems:
        print(p)
    print(f"[check_docs] {len(problems)} problem(s)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
