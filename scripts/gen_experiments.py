"""Generate EXPERIMENTS.md from the committed dry-run artifacts.

Reads artifacts/dryrun/*.json (the `python -m repro.launch.dryrun --all
--mesh both` sweep) and emits:
  * sweep health summary (compiled / skipped / errored, compile times);
  * per-cell roofline tables (single + multi mesh) with the layout column;
  * the layout-policy decision table: chosen layout, peak HBM, headroom
    and the per-candidate scoring that drove each serve-cell choice;
  * FL weight-exchange (fl_aggregate) traffic table on the multi mesh;
  * hbm_bytes calibration: our trip-count-aware totals vs XLA's
    once-counted bytes-accessed.

  PYTHONPATH=src python scripts/gen_experiments.py
"""
from __future__ import annotations

import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT))
sys.path.insert(0, str(ROOT / "src"))

import benchmarks.roofline as R

R.ARTIFACTS = ROOT / "artifacts" / "dryrun"


def sweep_summary() -> str:
    parts = []
    for mesh in ("single", "multi"):
        ok = skip = err = 0
        comp = []
        for rec in R.load_cells(mesh):
            if rec["status"] == "ok":
                ok += 1
                comp += [e["compile_s"] for e in rec["entries"].values()
                         if "compile_s" in e]
            elif rec["status"] == "skipped":
                skip += 1
            else:
                err += 1
        line = f"* `{mesh}` mesh: {ok} compiled, {skip} documented skips, " \
               f"{err} errors"
        if comp:
            comp.sort()
            line += (f"; per-program compile time min/median/max = "
                     f"{comp[0]:.1f}/{comp[len(comp)//2]:.1f}/"
                     f"{comp[-1]:.1f}s")
        parts.append(line)
    return "\n".join(parts)


def layout_table() -> str:
    out = ["| arch | shape | mesh | layout | cache | fits | peak GB/dev | "
           "headroom GB | stationary | hybrid | fsdp | why |\n",
           "|---|---|---|---|---|---|---|---|---|---|---|---|\n"]
    n_cells = n_fit = 0
    cap_gb = None

    def ckey(c):
        return (c["layout"] + (f"+{c['cache']}" if c.get("cache") else "")
                + ("+chunked" if c.get("chunked") else ""))

    for mesh in ("single", "multi"):
        for rec in R.load_cells(mesh):
            ld = rec.get("layout_decision")
            if not ld or "candidates" not in ld:
                continue
            n_cells += 1
            n_fit += bool(ld["fits"])
            cap_gb = ld["budget_gb"] * ld["margin"]
            # per-layout columns show the BASELINE (config-spec) probes;
            # a spec'd rescue appears in the cache column + chosen peak
            base_cand = {c["layout"]: c for c in ld["candidates"]
                         if not c.get("cache") and not c.get("chunked")}
            peak = {k: f"{c['hbm_gb']:.2f}" for k, c in base_cand.items()}
            chosen = ld["layout"]
            dkey = (chosen + (f"+{ld['cache_spec']}"
                              if ld.get("cache_spec") else "")
                    + ("+chunked" if ld.get("chunked") else ""))
            chosen_c = next((c for c in ld["candidates"] if ckey(c) == dkey),
                            base_cand.get(chosen))
            cache_cell = (ld.get("cache_spec") or "--") + \
                (" +chunked" if ld.get("chunked") else "")
            if not ld.get("cache_spec"):
                for k in peak:
                    if k == chosen:
                        peak[k] = f"**{peak[k]}**"
            why = ("rescued: spec'd cache" if ld["fits"]
                   and ld.get("cache_spec")
                   else "fastest feasible step" if ld["fits"]
                   else "nothing fits; min peak")
            out.append(
                f"| {rec['arch']} | {rec['shape']} | {mesh} | "
                f"**{chosen}** | {cache_cell} | "
                f"{'yes' if ld['fits'] else 'NO'} | "
                f"{chosen_c['hbm_gb']:.2f} | {ld['headroom_gb']:.2f} | "
                f"{peak.get('stationary', '--')} | "
                f"{peak.get('hybrid', '--')} | {peak.get('fsdp', '--')} | "
                f"{why} |\n")
    if cap_gb is not None:
        out.append(f"\n{n_fit}/{n_cells} serve cells fit under the "
                   f"{cap_gb:.1f} GB cap (margin x device HBM, from the "
                   f"recorded decisions).\n")
    return "".join(out)


def fl_agg_table() -> str:
    out = ["| arch | t_coll (ms) | t_mem (ms) | wire GB/dev | "
           "amortized / E=8 local steps (ms) |\n|---|---|---|---|---|\n"]
    for rec in R.load_cells("multi"):
        e = rec.get("entries", {}).get("fl_aggregate", {})
        if rec["status"] != "ok" or "roofline" not in e:
            continue
        r = e["roofline"]
        out.append(
            f"| {rec['arch']} | {r['t_collective_s']*1e3:.1f} | "
            f"{r['t_memory_s']*1e3:.1f} | "
            f"{e['hlo_cost']['collective_bytes']/1e9:.2f} | "
            f"{r['t_collective_s']*1e3/8:.1f} |\n")
    return "".join(out)


def calibration_table() -> str:
    out = ["| arch | shape | mesh | program | ours (GB) | XLA once (GB) "
           "| ratio |\n", "|---|---|---|---|---|---|---|\n"]
    ratios = []
    for mesh in ("single", "multi"):
        for rec in R.load_cells(mesh):
            if rec["status"] != "ok":
                continue
            for name, e in rec["entries"].items():
                if "hlo_cost" not in e:
                    continue
                ours = e["hlo_cost"]["hbm_bytes"]
                xla = e["xla_cost_analysis_once"]["bytes_accessed"]
                if xla <= 0:
                    continue
                ratios.append((ours / xla, rec["arch"], rec["shape"], mesh,
                               name, ours, xla))
    ratios.sort(key=lambda t: t[0])
    # show the extremes + the CNN cell the calibration targeted
    picked = ratios[:3] + ratios[-3:] + \
        [t for t in ratios if t[1].startswith("flight-cnn")]
    seen = set()
    for ratio, arch, shape, mesh, name, ours, xla in picked:
        key = (arch, shape, mesh, name)
        if key in seen:
            continue
        seen.add(key)
        out.append(f"| {arch} | {shape} | {mesh} | {name} | "
                   f"{ours/1e9:.2f} | {xla/1e9:.2f} | {ratio:.2f} |\n")
    if ratios:
        med = ratios[len(ratios) // 2][0]
        out.append(f"\nAcross {len(ratios)} compiled programs the "
                   f"ours/XLA ratio spans {ratios[0][0]:.2f}x to "
                   f"{ratios[-1][0]:.2f}x (median {med:.2f}x). Ratios "
                   f"well above 1 are scanned programs where XLA counts "
                   f"the loop body once and we multiply trip counts; "
                   f"before the fusion-boundary calibration the CNN "
                   f"train cell sat at ~3600x.\n")
    return "".join(out)


def exchange_section() -> str:
    """Compressed-exchange table from the committed BENCH_exchange.json
    (benchmarks/fl_exchange.py) -- measured, not analytic, so it can be
    refreshed without re-running the dry-run sweep (`--exchange-only`)."""
    import json
    path = ROOT / "BENCH_exchange.json"
    body = ["<!-- exchange:begin -->\n",
            "## Compressed exchange (measured, BENCH_exchange.json)\n\n",
            "From `benchmarks/fl_exchange.py`: bytes-on-wire and wall time "
            "of one jitted\nexchange round on a mixed-shape/dtype tree "
            "(~0.92 M params/island), per\nisland count and compression "
            "mode.  `q8` rides the sharding-preserving\nrowwise int8 "
            "layout; the top-k modes send `k_frac` of the delta\n"
            "(threshold-mask form).  See README \"Compressed exchange\".\n\n"]
    if not path.exists():
        body.append("*BENCH_exchange.json missing -- run "
                    "`PYTHONPATH=src python benchmarks/fl_exchange.py`.*\n")
        body.append("<!-- exchange:end -->\n")
        return "".join(body)
    bench = json.loads(path.read_text())
    body.append("| islands | mode | wire MB/round | reduction vs f32 | "
                "exchange ms |\n|---|---|---|---|---|\n")
    for cell in bench["cells"].values():
        body.append(
            f"| {cell['islands']} | {cell['mode']} | "
            f"{cell['wire_mb_per_round']:.3f} | "
            f"{cell['reduction_vs_f32']:.2f}x | "
            f"{cell['exchange_ms']:.2f} |\n")
    par = bench.get("parity", {})
    if par:
        body.append("\nPallas (interpret off-TPU) vs jnp-reference parity, "
                    "max-abs: "
                    + ", ".join(f"`{k}` = {v:.2e}" for k, v in par.items())
                    + f" (bound 1e-2; k_frac = {bench['k_frac']}).\n")
    body.append("<!-- exchange:end -->\n")
    return "".join(body)


def serve_section() -> str:
    """Paged-serving latency table from the committed BENCH_serve.json
    (benchmarks/serve_load.py) -- measured under open-loop load, so it can
    be refreshed without re-running the dry-run sweep (`--serve-only`)."""
    import json
    path = ROOT / "BENCH_serve.json"
    body = ["<!-- serve:begin -->\n",
            "## Paged serving under load (measured, BENCH_serve.json)\n\n",
            "From `benchmarks/serve_load.py`: the block-table paged serve "
            "loop\n(`PagedServeLoop`, core/paging.py allocator) vs the "
            "contiguous per-slot\ncache, driven by the seeded open-loop "
            "generator (`launch/loadgen.py`)\non the granite smoke model.  "
            "The parity row replays one trace through\nboth cache "
            "disciplines on a virtual clock and requires token-identical\n"
            "outputs.  See README \"Production serving\".\n\n"]
    if not path.exists():
        body.append("*BENCH_serve.json missing -- run "
                    "`PYTHONPATH=src python benchmarks/serve_load.py`.*\n")
        body.append("<!-- serve:end -->\n")
        return "".join(body)
    bench = json.loads(path.read_text())
    body.append("| cell | reqs | p50 ms | p99 ms | ttft p50 ms | "
                "tok/s | shared blocks | preempt |\n"
                "|---|---|---|---|---|---|---|---|\n")
    for name, c in sorted(bench["cells"].items()):
        body.append(
            f"| {name} | {c['n_requests']} | {c['p50_ms']:.0f} | "
            f"{c['p99_ms']:.0f} | {c['ttft_p50_ms']:.0f} | "
            f"{c['tokens_per_s']:.1f} | {c.get('shared_blocks', '--')} | "
            f"{c.get('preemptions', '--')} |\n")
    par = bench.get("parity", {})
    if par:
        body.append(
            f"\nParity: {par['mismatches']}/{par['n_requests']} requests "
            f"diverged between paged and contiguous greedy decode "
            f"({par['shared_blocks']} prefix blocks shared); the "
            f"invariant `mismatches == 0` is enforced on every "
            f"benchmark run.  Workload: {bench['workload']}; pool "
            f"{bench['pool']['num_blocks']}x{bench['pool']['block_size']} "
            f"blocks, chunk {bench['pool']['chunk']}.\n")
    body.append("<!-- serve:end -->\n")
    return "".join(body)


def faults_section() -> str:
    """Byzantine-robustness table from the committed BENCH_faults.json
    (benchmarks/fl_faults.py) -- measured, so it can be refreshed without
    re-running the dry-run sweep (`--faults-only`)."""
    import json
    path = ROOT / "BENCH_faults.json"
    body = ["<!-- faults:begin -->\n",
            "## Byzantine robustness (measured, BENCH_faults.json)\n\n",
            "From `benchmarks/fl_faults.py`: the scenario engine under a "
            "seeded fault plan\n(`core/faults.py`) -- 20% Byzantine "
            "workers shipping sign-flipped / 10x-scaled\nupdates -- "
            "comparing plain weighted FedAvg against the robust "
            "aggregators, plus\na nan/inf-spray cell where the "
            "sanitization gate alone must keep the model\nfinite.  See "
            "README \"Fault tolerance & robust aggregation\".\n\n"]
    if not path.exists():
        body.append("*BENCH_faults.json missing -- run "
                    "`PYTHONPATH=src python benchmarks/fl_faults.py`.*\n")
        body.append("<!-- faults:end -->\n")
        return "".join(body)
    bench = json.loads(path.read_text())
    body.append("| cell | aggregator | byz frac | best acc | final acc | "
                "finite | quarantined |\n|---|---|---|---|---|---|---|\n")
    for name, c in bench["cells"].items():
        body.append(
            f"| {name} | {c['robust_agg']} | {c['byzantine_frac']} | "
            f"{c['best_acc']:.4f} | {c['final_acc']:.4f} | "
            f"{'yes' if c['params_finite'] else 'NO'} | "
            f"{c['n_quarantined']} |\n")
    cells = bench["cells"]
    clean = cells["clean_fedavg"]["best_acc"]
    drop = clean - cells["attacked_fedavg"]["best_acc"]
    worst = max(clean - cells[n]["best_acc"] for n in
                ("attacked_trimmed", "attacked_krum", "attacked_median"))
    body.append(
        f"\nScenario: {bench['scenario']}.  Plain FedAvg loses "
        f"{drop:.3f} best accuracy under attack; the worst robust "
        f"aggregator's deficit vs the fault-free run is {worst:.4f} "
        f"(bound {bench['acc_tol']}).  Both bounds, and `params_finite` "
        f"for every cell, are enforced on every benchmark run.\n")
    body.append("<!-- faults:end -->\n")
    return "".join(body)


def _splice(section: str, begin: str, end: str, what: str) -> None:
    path = ROOT / "EXPERIMENTS.md"
    text = path.read_text()
    if begin in text:
        pre = text[: text.index(begin)]
        post = text[text.index(end) + len(end):]
        text = pre + section + post
    else:
        anchor = "## hbm_bytes calibration"
        text = text.replace(anchor, section + "\n" + anchor, 1)
    path.write_text(text)
    print(f"spliced {what} section into {path}")


def splice_faults() -> None:
    _splice(faults_section(), "<!-- faults:begin -->",
            "<!-- faults:end -->\n", "byzantine-robustness")


def splice_serve() -> None:
    """Replace (or insert) only the paged-serving section of the existing
    EXPERIMENTS.md, leaving the artifact-derived tables alone."""
    path = ROOT / "EXPERIMENTS.md"
    text = path.read_text()
    section = serve_section()
    begin, end = "<!-- serve:begin -->", "<!-- serve:end -->\n"
    if begin in text:
        pre = text[: text.index(begin)]
        post = text[text.index(end) + len(end):]
        text = pre + section + post
    else:
        anchor = "## hbm_bytes calibration"
        text = text.replace(anchor, section + "\n" + anchor, 1)
    path.write_text(text)
    print(f"spliced paged-serving section into {path}")


def splice_exchange() -> None:
    """Replace (or insert) only the compressed-exchange section of the
    existing EXPERIMENTS.md, leaving the artifact-derived tables alone --
    the dry-run sweep is expensive and its artifacts are not committed."""
    path = ROOT / "EXPERIMENTS.md"
    text = path.read_text()
    section = exchange_section()
    begin, end = "<!-- exchange:begin -->", "<!-- exchange:end -->\n"
    if begin in text:
        pre = text[: text.index(begin)]
        post = text[text.index(end) + len(end):]
        text = pre + section + post
    else:
        anchor = "## hbm_bytes calibration"
        text = text.replace(anchor, section + "\n" + anchor, 1)
    path.write_text(text)
    print(f"spliced compressed-exchange section into {path}")


HEADER = """\
# EXPERIMENTS — dry-run sweep, roofline tables, layout policy

Generated by `scripts/gen_experiments.py` from `artifacts/dryrun/*.json`
(the output of `PYTHONPATH=src python -m repro.launch.dryrun --all --mesh
both`).  Regenerate after re-running the sweep; do not edit the tables by
hand.

Conventions: flops / bytes are PER DEVICE from the trip-count-aware HLO
cost model (`repro/dist/hlo_cost.py`); the hardware model is one
v5e-class chip (197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI, 16 GB
HBM — see `repro/dist/hlo_analysis.py` and `repro/dist/policy.py`).
`mem GB/dev` is XLA's `memory_analysis` (arguments + temporaries).
Memory numbers come from the CPU backend's SPMD compile: temporaries are
pessimistic vs a real TPU lowering, so treat `fits` as a conservative
verdict.

## Sweep health

{SUMMARY}

## Layout policy decisions (serve cells)

For every prefill/decode cell the dry-run AOT-compiles all three weight
layouts — `stationary` (TP-only weights, replicated over data), `hybrid`
(stationary body + vocab tables sharded over data), `fsdp` (the training
layout) — and `repro.dist.policy` picks the fastest layout whose peak
per-device HBM fits under 90% of device HBM; with no fit it falls back
to the smallest peak (see `README.md` “How layout selection works”).
Peak GB columns show each candidate; the chosen one is bold.

{LAYOUT}

## Roofline — single-pod mesh (data=16, model=16; 256 chips)

{TABLE_SINGLE}

## Roofline — multi-pod mesh (pod=2, data=16, model=16; 512 chips)

On the multi-pod mesh `train_4k` runs the federated-island layout (one
island per pod) and additionally lowers the `fl_aggregate` weight
exchange.

{TABLE_MULTI}

## FL weight exchange (fl_aggregate, multi-pod mesh)

{FL_AGG}

As the paper's communication-cost analysis predicts, the exchange is
collective-bound for every arch; the amortized column divides by the
paper's E=8 local steps between exchanges.

{EXCHANGE}
{SERVE}
{FAULTS}
## hbm_bytes calibration (trip-count model vs XLA bytes-accessed)

{CALIBRATION}
"""


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--exchange-only", action="store_true",
                    help="re-splice just the compressed-exchange section "
                         "(from BENCH_exchange.json) into the existing "
                         "EXPERIMENTS.md; no dry-run artifacts needed")
    ap.add_argument("--serve-only", action="store_true",
                    help="re-splice just the paged-serving section "
                         "(from BENCH_serve.json) into the existing "
                         "EXPERIMENTS.md; no dry-run artifacts needed")
    ap.add_argument("--faults-only", action="store_true",
                    help="re-splice just the byzantine-robustness section "
                         "(from BENCH_faults.json) into the existing "
                         "EXPERIMENTS.md; no dry-run artifacts needed")
    args = ap.parse_args(argv)
    if args.exchange_only:
        splice_exchange()
        return
    if args.serve_only:
        splice_serve()
        return
    if args.faults_only:
        splice_faults()
        return
    single = R.markdown_table(
        [r for r in map(R.cell_row, R.load_cells("single")) if r])
    multi = R.markdown_table(
        [r for r in map(R.cell_row, R.load_cells("multi")) if r])
    out = HEADER.format(SUMMARY=sweep_summary(), LAYOUT=layout_table(),
                        TABLE_SINGLE=single, TABLE_MULTI=multi,
                        FL_AGG=fl_agg_table(), EXCHANGE=exchange_section(),
                        SERVE=serve_section(), FAULTS=faults_section(),
                        CALIBRATION=calibration_table())
    (ROOT / "EXPERIMENTS.md").write_text(out)
    print(f"wrote EXPERIMENTS.md ({len(out)} bytes)")


if __name__ == "__main__":
    main()
