"""Generate EXPERIMENTS.md from dry-run artifacts + benchmark logs."""
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import benchmarks.roofline as R

ROOT = Path(__file__).resolve().parents[1]


def rows_for(dirname, mesh):
    R.ARTIFACTS = ROOT / "artifacts" / dirname
    return [R.cell_row(rec) for rec in R.load_cells(mesh)]


def fmt_table(rows):
    hdr = ("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | dominant "
           "| useful | mem GB/dev |\n|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"*{r['status']}* | — | — |\n")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3g} | "
            f"{r['t_memory_s']:.3g} | {r['t_collective_s']:.3g} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['hbm_gb_per_dev']:.1f} |\n")
    return "".join(out)


def fl_agg_table(dirname):
    R.ARTIFACTS = ROOT / "artifacts" / dirname
    out = ["| arch | t_coll (ms) | t_mem (ms) | wire bytes/dev (GB) | "
           "amortized /E=8 local steps (ms) |\n|---|---|---|---|---|\n"]
    for rec in R.load_cells("multi"):
        if rec["status"] != "ok" or "fl_aggregate" not in rec.get("entries", {}):
            continue
        e = rec["entries"]["fl_aggregate"]
        if "roofline" not in e:
            continue
        r = e["roofline"]
        out.append(
            f"| {rec['arch']} | {r['t_collective_s']*1e3:.1f} | "
            f"{r['t_memory_s']*1e3:.1f} | "
            f"{e['hlo_cost']['collective_bytes']/1e9:.2f} | "
            f"{r['t_collective_s']*1e3/8:.1f} |\n")
    return "".join(out)


def bench_lines(path="bench_output.txt", kinds=("summary", "tta",
                                                   "policy", "best")):
    p = Path(path)
    if not p.exists():
        return "*(benchmark log not present at generation time)*\n"
    out = []
    for line in p.read_text().splitlines():
        if line.split(",")[0] in kinds:
            out.append(line)
    return "```\n" + "\n".join(out) + "\n```\n"


def dryrun_summary(dirname):
    R.ARTIFACTS = ROOT / "artifacts" / dirname
    parts = []
    for mesh in ("single", "multi"):
        ok = skip = err = 0
        comp = []
        for rec in R.load_cells(mesh):
            if rec["status"] == "ok":
                ok += 1
                for e in rec["entries"].values():
                    if "compile_s" in e:
                        comp.append(e["compile_s"])
            elif rec["status"] == "skipped":
                skip += 1
            else:
                err += 1
        parts.append(f"  * {mesh}: {ok} compiled, {skip} documented skips, "
                     f"{err} errors; compile time "
                     f"min/median/max = {min(comp):.1f}/"
                     f"{sorted(comp)[len(comp)//2]:.1f}/{max(comp):.1f}s")
    return "\n".join(parts)


TEMPLATE = open(ROOT / "scripts" / "experiments_template.md").read()

out = TEMPLATE
out = out.replace("{{DRYRUN_SUMMARY}}", dryrun_summary("dryrun_opt"))
out = out.replace("{{TABLE_SINGLE_OPT}}", fmt_table(rows_for("dryrun_opt", "single")))
out = out.replace("{{TABLE_MULTI_OPT}}", fmt_table(rows_for("dryrun_opt", "multi")))
out = out.replace("{{TABLE_SINGLE_BASE}}", fmt_table(rows_for("dryrun", "single")))
out = out.replace("{{FL_AGG_TABLE}}", fl_agg_table("dryrun_opt"))
out = out.replace("{{BENCH_SUMMARIES}}", bench_lines())
(ROOT / "EXPERIMENTS.md").write_text(out)
print("wrote EXPERIMENTS.md", len(out), "bytes")
