"""Fault-tolerance substrate: atomic, versioned checkpoints with elastic
re-sharding on restore.

  * atomic publish: write .tmp then os.replace -- a crash mid-save can never
    corrupt the latest checkpoint;
  * manifest.json records step/round/FL-policy state/extra metadata;
  * rotation keeps the newest K checkpoints;
  * ELASTIC restore: arrays are stored logically (unsharded); `restore`
    accepts a pytree of NamedShardings for a *different* mesh than the one
    that saved -- grow/shrink pods without conversion tools (an FL island
    that died simply resumes from the last aggregate, see DESIGN.md SS7).

At real 1000+-node scale the store would be tensorstore/OCDBT with
per-host shard files; the manager API is written so only save_pytree /
load_pytree would change.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _to_native(arr):
    """npz can't store ml_dtypes (bf16/fp8): upcast to fp32 on disk; the
    restore path casts back to the template's dtype."""
    arr = np.asarray(arr)
    if arr.dtype.kind == "V" or arr.dtype.name in ("bfloat16", "float8_e4m3fn",
                                                   "float8_e5m2"):
        return arr.astype(np.float32)
    return arr


def save_pytree(tree, path: Path):
    """Atomic .npz save of any pytree of arrays."""
    path = Path(path)
    leaves, _ = jax.tree.flatten(tree)
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as f:  # file handle: savez won't append a suffix
        np.savez(f, **{f"a{i}": _to_native(l) for i, l in enumerate(leaves)})
    os.replace(tmp, path)


def load_pytree(path: Path, like_tree):
    """Restore into the structure of `like_tree` (treedef source of truth)."""
    _, treedef = jax.tree.flatten(like_tree)
    with np.load(path) as z:
        n = len([k for k in z.files if k.startswith("a")])
        leaves = [z[f"a{i}"] for i in range(n)]
    return jax.tree.unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str | Path, *, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._recover()

    # ---- save ----
    def save(self, step: int, *, params, opt_state=None, extra: Optional[dict]
             = None):
        ckpt = self.dir / f"step_{step:010d}"
        tmp = self.dir / f".tmp_step_{step:010d}"
        old = self.dir / f".old_step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        save_pytree(params, tmp / "params.npz")
        if opt_state is not None:
            save_pytree(opt_state, tmp / "opt_state.npz")
        manifest = {"step": int(step), "time": time.time(),
                    "extra": extra or {}}
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
        # Overwrite without a crash window: rename the published dir ASIDE
        # (atomic), publish the new one (atomic), only then delete the old.
        # A crash at any point leaves a loadable copy of this step on disk
        # (either step_X or .old_step_X; _recover() renames the latter
        # back).  The previous rmtree-then-replace sequence lost the
        # checkpoint when killed between the two calls.
        if old.exists():
            shutil.rmtree(old)
        if ckpt.exists():
            os.replace(ckpt, old)
        os.replace(tmp, ckpt)  # atomic publish
        if old.exists():
            shutil.rmtree(old)
        self._rotate()
        return ckpt

    def _recover(self):
        """Finish an interrupted overwrite: a .old_step_X with no published
        step_X means the crash hit between un-publish and re-publish --
        restore the old copy (it is a complete, previously published
        checkpoint).  A .old with a published sibling is garbage from a
        crash after publish; delete it, along with stale .tmp dirs."""
        for p in self.dir.glob(".old_step_*"):
            step = p.name.split("_")[-1]
            published = self.dir / f"step_{step}"
            if published.exists():
                shutil.rmtree(p, ignore_errors=True)
            else:
                os.replace(p, published)
        for p in self.dir.glob(".tmp_step_*"):
            shutil.rmtree(p, ignore_errors=True)

    def _rotate(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # ---- discovery ----
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def path_for(self, step: int) -> Path:
        return self.dir / f"step_{step:010d}"

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ---- restore ----
    def restore(self, *, params_like, opt_state_like=None, step: Optional[int]
                = None, shardings=None, opt_shardings=None):
        """Returns (step, params, opt_state, extra).  `shardings` may target
        ANY mesh (elastic re-shard: logical arrays are device_put to the new
        layout)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        ckpt = self.dir / f"step_{step:010d}"
        manifest = json.loads((ckpt / "manifest.json").read_text())
        params = load_pytree(ckpt / "params.npz", params_like)
        params = jax.tree.map(
            lambda arr, like: np.asarray(arr, dtype=like.dtype),
            params, params_like)
        if shardings is not None:
            params = jax.tree.map(jax.device_put, params, shardings)
        opt_state = None
        if opt_state_like is not None and (ckpt / "opt_state.npz").exists():
            opt_state = load_pytree(ckpt / "opt_state.npz", opt_state_like)
            if opt_shardings is not None:
                opt_state = jax.tree.map(jax.device_put, opt_state,
                                         opt_shardings)
        return step, params, opt_state, manifest.get("extra", {})
