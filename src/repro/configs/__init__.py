"""Architecture registry: one module per assigned arch (+ the paper's own
Tier-A models).  `get_config(name)` returns the FULL config (dry-run only);
`get_smoke_config(name)` returns the reduced same-family config used by CPU
smoke tests and the FL simulator."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, ShapeConfig, SHAPES

_ARCHS = {
    "mixtral-8x22b": "mixtral_8x22b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "qwen1.5-4b": "qwen1_5_4b",
    "chatglm3-6b": "chatglm3_6b",
    "granite-20b": "granite_20b",
    "minitron-8b": "minitron_8b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    # the paper's own workloads (Tier-A FL experiments)
    "flight-cnn-mnist": "flight_cnn",
    "flight-cnn-cifar": "flight_cnn",
}


def _module(name: str):
    if name not in _ARCHS:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_ARCHS)}")
    return importlib.import_module(f"repro.configs.{_ARCHS[name]}")


def get_config(name: str) -> ModelConfig:
    mod = _module(name)
    if name == "flight-cnn-cifar":
        return mod.CONFIG_CIFAR
    if name == "flight-cnn-mnist":
        return mod.CONFIG_MNIST
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    mod = _module(name)
    if name.startswith("flight-cnn"):
        return get_config(name)  # already tiny
    return mod.SMOKE


def list_archs(assigned_only: bool = True):
    names = [n for n in _ARCHS if not n.startswith("flight-")] if assigned_only \
        else list(_ARCHS)
    return names
