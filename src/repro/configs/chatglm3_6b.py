"""chatglm3-6b [dense] -- 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024, 2d (partial) RoPE.  [arXiv:2406.12793; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2, head_dim=128,
    d_ff=13696, vocab_size=65024,
    qkv_bias=True, attention="full", rope_fraction=0.5,
    norm="rmsnorm", act="silu",
    grad_accum=4,
)

SMOKE = ModelConfig(
    name="chatglm3-6b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
    d_ff=160, vocab_size=499,
    qkv_bias=True, attention="full", rope_fraction=0.5,
    norm="rmsnorm", act="silu", remat=False,
)
