"""falcon-mamba-7b [ssm] -- 64L d_model=4096 attention-free vocab=65024,
mamba-1 architecture with ssm_state=16.  [arXiv:2410.05355]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    num_layers=64, d_model=4096, num_heads=0, num_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=65024,
    ssm_state=16, ssm_expand=2, conv_width=4,
    norm="rmsnorm", act="silu",
    grad_accum=8,
)

SMOKE = ModelConfig(
    name="falcon-mamba-7b-smoke", family="ssm",
    num_layers=2, d_model=64, num_heads=0, num_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=499,
    ssm_state=4, ssm_expand=2, conv_width=4,
    norm="rmsnorm", act="silu", remat=False,
)
