"""The paper's own Tier-A workloads: small CNN classifiers federated over
heterogeneous workers (MNIST / CIFAR-10 experiments, Figs. 12-18)."""
from repro.models.config import ModelConfig

CONFIG_MNIST = ModelConfig(
    name="flight-cnn-mnist", family="cnn",
    num_layers=2, d_model=0,
    img_hw=28, img_c=1, cnn_channels=(16, 32), n_classes=10,
    remat=False,
)

CONFIG_CIFAR = ModelConfig(
    name="flight-cnn-cifar", family="cnn",
    num_layers=2, d_model=0,
    img_hw=32, img_c=3, cnn_channels=(32, 64), n_classes=10,
    remat=False,
)
