"""granite-20b [dense] -- 52L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152, code model.  [arXiv:2405.04324; hf]

d_ff = 4*d_model with MQA indicates a plain (non-gated) MLP, gpt-bigcode
style; we keep RoPE+RMSNorm per the 'llama-arch' note in the assignment."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", family="dense",
    num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1, head_dim=128,
    d_ff=24576, vocab_size=49152,
    attention="full",
    norm="rmsnorm", act="gelu_plain",
    grad_accum=16,
)

SMOKE = ModelConfig(
    name="granite-20b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
    d_ff=256, vocab_size=499,
    attention="full",
    norm="rmsnorm", act="gelu_plain", remat=False,
)
