"""minitron-8b [dense] -- 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000, pruned nemotron (squared-ReLU plain MLP, LayerNorm).
[arXiv:2407.14679; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=256000,
    attention="full",
    norm="layernorm", act="relu2",
    grad_accum=8,
)

SMOKE = ModelConfig(
    name="minitron-8b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
    d_ff=192, vocab_size=997,
    attention="full",
    norm="layernorm", act="relu2", remat=False,
)
