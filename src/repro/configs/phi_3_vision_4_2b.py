"""phi-3-vision-4.2b [vlm] -- 32L d_model=3072 32H (MHA kv=32) d_ff=8192
vocab=32064, phi3-mini backbone + CLIP frontend (STUB: input_specs provides
576 precomputed patch embeddings occupying the sequence prefix).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32, head_dim=96,
    d_ff=8192, vocab_size=32064,
    attention="full",
    frontend="vision_stub", frontend_len=576,
    norm="rmsnorm", act="silu",
    grad_accum=4,
)

SMOKE = ModelConfig(
    name="phi-3-vision-4.2b-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=499,
    attention="full",
    frontend="vision_stub", frontend_len=8,
    norm="rmsnorm", act="silu", remat=False,
)
