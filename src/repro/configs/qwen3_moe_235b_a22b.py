"""qwen3-moe-235b-a22b [moe] -- 94L d_model=4096 64H (GQA kv=4) expert
d_ff=1536 vocab=151936, MoE 128 experts top-8.
[hf:Qwen/Qwen3-30B-A3B family scaling; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4, head_dim=64,
    d_ff=1536, vocab_size=151936,
    num_experts=128, experts_per_token=8, moe_d_ff=1536,
    attention="full",
    norm="rmsnorm", act="silu", rope_theta=1e6,
    grad_accum=16,
)

SMOKE = ModelConfig(
    name="qwen3-moe-235b-a22b-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
    d_ff=96, vocab_size=499,
    num_experts=8, experts_per_token=4, moe_d_ff=96,
    capacity_factor=0.0,  # dropless: decode must match teacher forcing
    attention="full",
    norm="rmsnorm", act="silu", remat=False,
)
