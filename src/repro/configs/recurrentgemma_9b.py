"""recurrentgemma-9b [hybrid] -- 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000, RG-LRU + local attention in a 2:1 pattern (Griffin).
[arXiv:2402.19427]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1, head_dim=256,
    d_ff=12288, vocab_size=256000,
    attention="local", window=2048,
    lru_width=4096, conv_width=4,
    pattern_recurrent=2, pattern_attention=1,
    norm="rmsnorm", act="gelu",
    grad_accum=8,
)

SMOKE = ModelConfig(
    name="recurrentgemma-9b-smoke", family="hybrid",
    num_layers=5, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=499,
    attention="local", window=8,
    lru_width=64, conv_width=4,
    pattern_recurrent=2, pattern_attention=1,
    norm="rmsnorm", act="gelu", remat=False,
)
