"""seamless-m4t-large-v2 [audio] -- enc-dec, 24L(+24L enc) d_model=1024
16H (MHA kv=16) d_ff=8192 vocab=256206.  The audio frontend is a STUB:
input_specs provides precomputed frame embeddings.  [arXiv:2308.11596; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    num_layers=24, enc_layers=24, is_encdec=True,
    d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=8192, vocab_size=256206,
    attention="full",
    norm="layernorm", act="gelu_plain",
    grad_accum=4,
)

SMOKE = ModelConfig(
    name="seamless-m4t-large-v2-smoke", family="audio",
    num_layers=2, enc_layers=2, is_encdec=True,
    d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=499,
    attention="full",
    norm="layernorm", act="gelu_plain", remat=False,
)
