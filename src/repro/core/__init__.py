# FLight core: the paper's primary contribution in JAX.
#   aggregation -- FedAvg + weighted/staleness variants + island mixing
#   selection   -- Algorithm 1 (rmin/rmax), Algorithm 2 (time-based), baselines
#   cost_model  -- Eq. 4 system-parameter time estimation + profiles
#   client      -- local training on private shards
#   server      -- versioned aggregation server + policy feedback (Eq. 1-3)
#   events      -- discrete-event sync/async FL engine (paper experiments)
#   federated   -- Tier B: FL as one mixing collective over the pod axis
#   hierarchy   -- two-tier edge->fog->cloud aggregation (== flat, by test)
#   scenarios   -- 10^5-worker churn/straggler/drift scenario engine
#   warehouse   -- pointer-addressed weight store w/ one-time credentials
#   compression -- int8 delta compression with error feedback (beyond-paper)
from repro.core import (aggregation, client, compression, cost_model, events,
                        federated, hierarchy, scenarios, selection, server,
                        warehouse)
