"""Aggregation algorithms (paper SSII-A / SSIII-C.4).

All operate on parameter pytrees.  The paper's four families:
  * federated averaging          -- weights proportional to worker data size
  * linear weighted averaging    -- staleness-discounted, linear decay
  * polynomial weighted          -- (staleness+1)^-a decay
  * exponential weighted         -- exp(-lam*staleness) decay
plus the asynchronous single-worker merge (server folds one response into
its model as soon as it arrives; paper SSIII-C.4: weights arriving during an
aggregation are deferred to the next round, never dropped).

Averaging is computed in fp32 regardless of the storage dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# Weighting schemes
# --------------------------------------------------------------------------

def aggregation_weights(
    scheme: str,
    n_data: Sequence[float],
    staleness: Sequence[float] | None = None,
    *,
    poly_a: float = 1.0,
    exp_lam: float = 0.5,
    lin_lam: float = 0.25,
) -> np.ndarray:
    """Normalised per-worker weights for one aggregation round."""
    n = np.asarray(n_data, np.float64)
    s = np.zeros_like(n) if staleness is None else np.asarray(staleness,
                                                              np.float64)
    if scheme == "uniform":
        w = np.ones_like(n)
    elif scheme == "fedavg":
        w = n
    elif scheme == "linear":
        w = n * np.maximum(0.0, 1.0 - lin_lam * s)
    elif scheme == "polynomial":
        w = n * np.power(1.0 + s, -poly_a)
    elif scheme == "exponential":
        w = n * np.exp(-exp_lam * s)
    else:
        raise ValueError(f"unknown aggregation scheme '{scheme}'")
    tot = w.sum()
    if tot <= 0:  # every candidate fully discounted -> fall back to uniform
        w = np.ones_like(n)
        tot = w.sum()
    return (w / tot).astype(np.float64)


# --------------------------------------------------------------------------
# Pytree merges
# --------------------------------------------------------------------------

def weighted_average(param_list, weights) -> "pytree":
    """sum_i w_i * params_i, computed in fp32, cast back to leaf dtype."""
    w = np.asarray(weights, np.float64)
    assert len(param_list) == len(w) and abs(float(w.sum()) - 1.0) < 1e-6, \
        (len(param_list), w.sum())

    def merge(*leaves):
        acc = jnp.zeros(leaves[0].shape, jnp.float32)
        for wi, leaf in zip(w, leaves):
            acc = acc + jnp.float32(wi) * leaf.astype(jnp.float32)
        return acc.astype(leaves[0].dtype)

    return jax.tree.map(merge, *param_list)


def async_merge(server_params, worker_params, alpha: float):
    """M_s <- (1-a) M_s + a M_w  (asynchronous single-response fold)."""
    a = float(alpha)

    def merge(s, w_):
        return ((1.0 - a) * s.astype(jnp.float32)
                + a * w_.astype(jnp.float32)).astype(s.dtype)

    return jax.tree.map(merge, server_params, worker_params)


def staleness_alpha(base_alpha: float, staleness: float, *,
                    scheme: str = "polynomial", poly_a: float = 0.5,
                    exp_lam: float = 0.3) -> float:
    """Mixing rate for async merges, decayed by version lag (FedAsync-style;
    the paper's 'biased to newer versions of the aggregation server model')."""
    s = max(0.0, float(staleness))
    if scheme == "constant":
        d = 1.0
    elif scheme == "polynomial":
        d = (1.0 + s) ** (-poly_a)
    elif scheme == "exponential":
        d = float(np.exp(-exp_lam * s))
    else:
        raise ValueError(scheme)
    return float(base_alpha) * d


# --------------------------------------------------------------------------
# Sanitization helpers (the server-side gate; see server.AggregationServer)
# --------------------------------------------------------------------------

def tree_finite(tree) -> bool:
    """True iff every entry of every leaf is finite (no NaN/Inf)."""
    for leaf in jax.tree.leaves(tree):
        if not bool(jnp.all(jnp.isfinite(jnp.asarray(leaf, jnp.float32)))):
            return False
    return True


def delta_norm(tree, base) -> float:
    """Global L2 norm of (tree - base) across all leaves, in fp32."""
    acc = 0.0
    for t, b in zip(jax.tree.leaves(tree), jax.tree.leaves(base)):
        d = jnp.asarray(t, jnp.float32) - jnp.asarray(b, jnp.float32)
        acc += float(jnp.sum(d * d))
    return float(np.sqrt(acc))


# --------------------------------------------------------------------------
# Byzantine-robust aggregators (defense half of core/faults.py)
# --------------------------------------------------------------------------

ROBUST_METHODS = ("trimmed_mean", "median", "krum", "norm_clip")


def _stack_trees(param_list):
    return jax.tree.map(lambda *ls: jnp.stack(
        [jnp.asarray(l, jnp.float32) for l in ls]), *param_list)


def _flatten_members(stacked) -> jnp.ndarray:
    """(P, D) matrix: each member's leaves flattened and concatenated."""
    P = jax.tree.leaves(stacked)[0].shape[0]
    return jnp.concatenate(
        [jnp.asarray(l, jnp.float32).reshape(P, -1)
         for l in jax.tree.leaves(stacked)], axis=1)


def trim_k(n_members: int, trim_frac: float) -> int:
    """Entries trimmed per SIDE: ceil(frac * P), clamped so at least one
    member survives.  ceil means frac matching the Byzantine fraction
    always trims at least that many."""
    k = int(np.ceil(max(float(trim_frac), 0.0) * n_members))
    return min(k, (n_members - 1) // 2)


def krum_select(stacked, f: int, m: int | None = None) -> np.ndarray:
    """Multi-Krum selection (Blanchard et al. 2017): score each member by
    the sum of its P - f - 2 smallest squared distances to the others,
    return the indices of the m lowest-scoring members (m = P - f by
    default).  Requires no trust assumptions beyond f < (P - 2) / 2;
    f is clamped into that range."""
    X = _flatten_members(stacked)
    P = X.shape[0]
    f = max(0, min(int(f), (P - 3) // 2)) if P >= 3 else 0
    m = P - f if m is None else max(1, min(int(m), P))
    if P <= 2:
        return np.arange(P)
    sq = jnp.sum(X * X, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (X @ X.T)
    d2 = jnp.where(jnp.eye(P, dtype=bool), jnp.inf, jnp.maximum(d2, 0.0))
    n_near = max(1, P - f - 2)
    scores = jnp.sum(jnp.sort(d2, axis=1)[:, :n_near], axis=1)
    order = np.asarray(jnp.argsort(scores))
    return np.sort(order[:m])


def robust_aggregate_stacked(stacked, method: str, *, trim_frac: float = 0.2,
                             krum_f: int | None = None,
                             krum_m: int | None = None,
                             base=None, clip_mult: float = 2.0,
                             weights=None):
    """Robust fold of a stacked (P, ...) member tree into ONE aggregate.

    trimmed_mean / median / krum are deliberately UNWEIGHTED: data-size
    weighting would let an attacker buy influence by advertising samples.
    norm_clip keeps the weighted mean but first clips every member's
    delta-from-`base` to clip_mult x the median delta norm (needs `base`).
    """
    P = jax.tree.leaves(stacked)[0].shape[0]
    if method == "trimmed_mean":
        k = trim_k(P, trim_frac)

        def tm(leaf):
            x = jnp.sort(jnp.asarray(leaf, jnp.float32), axis=0)
            x = x[k: P - k] if k > 0 else x
            return jnp.mean(x, axis=0).astype(leaf.dtype)
        return jax.tree.map(tm, stacked)

    if method == "median":
        return jax.tree.map(
            lambda l: jnp.median(jnp.asarray(l, jnp.float32), axis=0)
            .astype(l.dtype), stacked)

    if method == "krum":
        f = int(np.ceil(0.2 * P)) if krum_f is None else int(krum_f)
        sel = krum_select(stacked, f, krum_m)
        return jax.tree.map(
            lambda l: jnp.mean(jnp.asarray(l, jnp.float32)[sel], axis=0)
            .astype(l.dtype), stacked)

    if method == "norm_clip":
        if base is None:
            raise ValueError("norm_clip needs the dispatch base")
        X = _flatten_members(stacked)
        b = _flatten_members(jax.tree.map(lambda x: x[None],
                                          base)).reshape(-1)
        norms = jnp.linalg.norm(X - b[None, :], axis=1)
        thr = clip_mult * jnp.median(norms)
        scale = np.asarray(jnp.minimum(1.0, thr / jnp.maximum(norms, 1e-12)))
        w = np.full(P, 1.0 / P) if weights is None else \
            np.asarray(weights, np.float64) / max(np.sum(weights), 1e-12)

        def nc(leaf, bleaf):
            l32 = jnp.asarray(leaf, jnp.float32)
            b32 = jnp.asarray(bleaf, jnp.float32)
            s = jnp.asarray(scale, jnp.float32).reshape(
                (P,) + (1,) * (l32.ndim - 1))
            clipped = b32[None] + s * (l32 - b32[None])
            wv = jnp.asarray(w, jnp.float32).reshape(
                (P,) + (1,) * (l32.ndim - 1))
            return jnp.sum(wv * clipped, axis=0).astype(leaf.dtype)
        return jax.tree.map(nc, stacked, base)

    raise ValueError(f"unknown robust method '{method}' "
                     f"(have {ROBUST_METHODS})")


def robust_aggregate(param_list, method: str, **kw):
    """List-of-pytrees front-end for `robust_aggregate_stacked` (Tier A:
    the discrete-event server's responses)."""
    if not param_list:
        raise ValueError("no updates to aggregate")
    template = param_list[0]
    out = robust_aggregate_stacked(_stack_trees(param_list), method, **kw)
    return jax.tree.map(lambda o, t: o.astype(t.dtype), out, template)


# --------------------------------------------------------------------------
# Mixing-matrix form (Tier B: one collective over the pod axis)
# --------------------------------------------------------------------------

def sync_mixing_matrix(weights: np.ndarray) -> np.ndarray:
    """Every island receives the same weighted average: M = 1 w^T."""
    w = np.asarray(weights, np.float64)
    P = w.shape[0]
    return np.tile(w[None, :], (P, 1))


def async_mixing_matrix(alphas: np.ndarray, contributors: np.ndarray
                        ) -> np.ndarray:
    """Island i keeps (1-a_i) of itself and takes a_i of the contributor mix.

    alphas: (P,) per-island mixing rates (0 => island unchanged this round);
    contributors: (P,) nonnegative contribution weights (who is 'fresh').
    """
    a = np.asarray(alphas, np.float64)
    c = np.asarray(contributors, np.float64)
    c = c / max(c.sum(), 1e-12)
    P = a.shape[0]
    M = np.diag(1.0 - a) + np.outer(a, c)
    assert np.allclose(M.sum(axis=1), 1.0)
    return M


def mix_islands(stacked_params, mixing: jnp.ndarray):
    """new_i = sum_j M[i,j] params_j over the leading island axis.

    Lowered inside jit this is the paper's whole weight-exchange step as ONE
    collective over the pod axis (see core/federated.py).  bf16 leaves are
    contracted in their STORAGE dtype with fp32 accumulation, so the pod
    collective moves bf16 -- an upfront f32 cast doubled the exchange bytes
    (EXPERIMENTS.md SSPerf, fl_aggregate iteration 1)."""

    def mix(leaf):
        if leaf.dtype == jnp.bfloat16:
            # bf16 on the wire: an elementwise weighted sum (NOT a dot --
            # dots legalise to f32 and put an f32 all-reduce on the pod
            # axis, 2x the bytes; measured in EXPERIMENTS.md SSPerf).
            # islands are few (P<=2 here), so bf16 accumulation is exact
            # enough for weight averaging.
            P = leaf.shape[0]
            w = mixing.astype(jnp.bfloat16).reshape(
                (P, P) + (1,) * (leaf.ndim - 1))
            return jnp.sum(w * leaf[None], axis=1)
        out = jnp.tensordot(mixing.astype(jnp.float32),
                            leaf.astype(jnp.float32), axes=1)
        return out.astype(leaf.dtype)

    return jax.tree.map(mix, stacked_params)
