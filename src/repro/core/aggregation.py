"""Aggregation algorithms (paper SSII-A / SSIII-C.4).

All operate on parameter pytrees.  The paper's four families:
  * federated averaging          -- weights proportional to worker data size
  * linear weighted averaging    -- staleness-discounted, linear decay
  * polynomial weighted          -- (staleness+1)^-a decay
  * exponential weighted         -- exp(-lam*staleness) decay
plus the asynchronous single-worker merge (server folds one response into
its model as soon as it arrives; paper SSIII-C.4: weights arriving during an
aggregation are deferred to the next round, never dropped).

Averaging is computed in fp32 regardless of the storage dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# Weighting schemes
# --------------------------------------------------------------------------

def aggregation_weights(
    scheme: str,
    n_data: Sequence[float],
    staleness: Sequence[float] | None = None,
    *,
    poly_a: float = 1.0,
    exp_lam: float = 0.5,
    lin_lam: float = 0.25,
) -> np.ndarray:
    """Normalised per-worker weights for one aggregation round."""
    n = np.asarray(n_data, np.float64)
    s = np.zeros_like(n) if staleness is None else np.asarray(staleness,
                                                              np.float64)
    if scheme == "uniform":
        w = np.ones_like(n)
    elif scheme == "fedavg":
        w = n
    elif scheme == "linear":
        w = n * np.maximum(0.0, 1.0 - lin_lam * s)
    elif scheme == "polynomial":
        w = n * np.power(1.0 + s, -poly_a)
    elif scheme == "exponential":
        w = n * np.exp(-exp_lam * s)
    else:
        raise ValueError(f"unknown aggregation scheme '{scheme}'")
    tot = w.sum()
    if tot <= 0:  # every candidate fully discounted -> fall back to uniform
        w = np.ones_like(n)
        tot = w.sum()
    return (w / tot).astype(np.float64)


# --------------------------------------------------------------------------
# Pytree merges
# --------------------------------------------------------------------------

def weighted_average(param_list, weights) -> "pytree":
    """sum_i w_i * params_i, computed in fp32, cast back to leaf dtype."""
    w = np.asarray(weights, np.float64)
    assert len(param_list) == len(w) and abs(float(w.sum()) - 1.0) < 1e-6, \
        (len(param_list), w.sum())

    def merge(*leaves):
        acc = jnp.zeros(leaves[0].shape, jnp.float32)
        for wi, leaf in zip(w, leaves):
            acc = acc + jnp.float32(wi) * leaf.astype(jnp.float32)
        return acc.astype(leaves[0].dtype)

    return jax.tree.map(merge, *param_list)


def async_merge(server_params, worker_params, alpha: float):
    """M_s <- (1-a) M_s + a M_w  (asynchronous single-response fold)."""
    a = float(alpha)

    def merge(s, w_):
        return ((1.0 - a) * s.astype(jnp.float32)
                + a * w_.astype(jnp.float32)).astype(s.dtype)

    return jax.tree.map(merge, server_params, worker_params)


def staleness_alpha(base_alpha: float, staleness: float, *,
                    scheme: str = "polynomial", poly_a: float = 0.5,
                    exp_lam: float = 0.3) -> float:
    """Mixing rate for async merges, decayed by version lag (FedAsync-style;
    the paper's 'biased to newer versions of the aggregation server model')."""
    s = max(0.0, float(staleness))
    if scheme == "constant":
        d = 1.0
    elif scheme == "polynomial":
        d = (1.0 + s) ** (-poly_a)
    elif scheme == "exponential":
        d = float(np.exp(-exp_lam * s))
    else:
        raise ValueError(scheme)
    return float(base_alpha) * d


# --------------------------------------------------------------------------
# Mixing-matrix form (Tier B: one collective over the pod axis)
# --------------------------------------------------------------------------

def sync_mixing_matrix(weights: np.ndarray) -> np.ndarray:
    """Every island receives the same weighted average: M = 1 w^T."""
    w = np.asarray(weights, np.float64)
    P = w.shape[0]
    return np.tile(w[None, :], (P, 1))


def async_mixing_matrix(alphas: np.ndarray, contributors: np.ndarray
                        ) -> np.ndarray:
    """Island i keeps (1-a_i) of itself and takes a_i of the contributor mix.

    alphas: (P,) per-island mixing rates (0 => island unchanged this round);
    contributors: (P,) nonnegative contribution weights (who is 'fresh').
    """
    a = np.asarray(alphas, np.float64)
    c = np.asarray(contributors, np.float64)
    c = c / max(c.sum(), 1e-12)
    P = a.shape[0]
    M = np.diag(1.0 - a) + np.outer(a, c)
    assert np.allclose(M.sum(axis=1), 1.0)
    return M


def mix_islands(stacked_params, mixing: jnp.ndarray):
    """new_i = sum_j M[i,j] params_j over the leading island axis.

    Lowered inside jit this is the paper's whole weight-exchange step as ONE
    collective over the pod axis (see core/federated.py).  bf16 leaves are
    contracted in their STORAGE dtype with fp32 accumulation, so the pod
    collective moves bf16 -- an upfront f32 cast doubled the exchange bytes
    (EXPERIMENTS.md SSPerf, fl_aggregate iteration 1)."""

    def mix(leaf):
        if leaf.dtype == jnp.bfloat16:
            # bf16 on the wire: an elementwise weighted sum (NOT a dot --
            # dots legalise to f32 and put an f32 all-reduce on the pod
            # axis, 2x the bytes; measured in EXPERIMENTS.md SSPerf).
            # islands are few (P<=2 here), so bf16 accumulation is exact
            # enough for weight averaging.
            P = leaf.shape[0]
            w = mixing.astype(jnp.bfloat16).reshape(
                (P, P) + (1,) * (leaf.ndim - 1))
            return jnp.sum(w * leaf[None], axis=1)
        out = jnp.tensordot(mixing.astype(jnp.float32),
                            leaf.astype(jnp.float32), axes=1)
        return out.astype(leaf.dtype)

    return jax.tree.map(mix, stacked_params)
