"""FL worker: local training on a private data shard (paper SSIII-C.3).

Local training is a single jitted scan over (epochs x minibatches); the
worker never shares raw data, only the resulting weights -- the FL
invariant.  Used by the Tier-A simulator and the examples.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def softmax_xent(logits, labels):
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (logz - gold).mean()


def accuracy(logits, labels):
    return (jnp.argmax(logits, axis=-1) == labels).mean()


@dataclasses.dataclass
class LocalTrainer:
    """SGD-with-momentum local trainer for classifier models."""
    model: object                 # repro.models.Model
    lr: float = 0.05
    momentum: float = 0.9
    batch_size: int = 64

    def __post_init__(self):
        self._train = jax.jit(self._train_impl, static_argnames=("epochs",))
        self._eval = jax.jit(self._eval_impl)

        def cohort_impl(params, images, labels, keys, epochs):
            return jax.vmap(
                lambda im, la, k: self._train_impl(params, im, la, k,
                                                   epochs=epochs)
            )(images, labels, keys)

        self._train_cohort = jax.jit(cohort_impl,
                                     static_argnames=("epochs",))

        def finite_ok(tree):
            return jnp.all(jnp.stack(
                [jnp.all(jnp.isfinite(l.astype(jnp.float32)))
                 for l in jax.tree.leaves(tree)]))

        def finite_members(stacked):
            """(C,) per-member finiteness over a stacked cohort tree."""
            oks = [jnp.all(jnp.isfinite(l.astype(jnp.float32)),
                           axis=tuple(range(1, l.ndim)))
                   for l in jax.tree.leaves(stacked)]
            return jnp.all(jnp.stack(oks, axis=0), axis=0)

        self._finite_ok = jax.jit(finite_ok)
        self._finite_members = jax.jit(finite_members)

    def _loss(self, params, images, labels):
        logits, aux = self.model.apply(params, {"images": images},
                                       mode="train")
        return softmax_xent(logits, labels) + 0.01 * aux

    def _train_impl(self, params, images, labels, key, *, epochs: int):
        n = images.shape[0]
        bs = min(self.batch_size, n)
        nb = max(n // bs, 1)
        mom = jax.tree.map(jnp.zeros_like, params)

        def epoch_step(carry, ekey):
            params, mom = carry
            perm = jax.random.permutation(ekey, n)[: nb * bs].reshape(nb, bs)

            def batch_step(carry, idx):
                params, mom = carry
                g = jax.grad(self._loss)(params, images[idx], labels[idx])
                mom = jax.tree.map(lambda m, gg: self.momentum * m + gg, mom, g)
                params = jax.tree.map(lambda p, m: p - self.lr * m, params, mom)
                return (params, mom), None

            (params, mom), _ = jax.lax.scan(batch_step, (params, mom), perm)
            return (params, mom), None

        (params, mom), _ = jax.lax.scan(epoch_step, (params, mom),
                                        jax.random.split(key, epochs))
        return params

    def _eval_impl(self, params, images, labels):
        logits, _ = self.model.apply(params, {"images": images}, mode="train")
        return accuracy(logits, labels)

    def train(self, params, images, labels, key, epochs: int):
        return self._train(params, images, labels, key, epochs=int(epochs))

    def train_checked(self, params, images, labels, key, epochs: int):
        """`train` with the non-finite guard: a diverged local step (any
        NaN/Inf in the result) is SKIPPED -- the input params come back
        unchanged with ok=False so the caller can report the divergence
        (the server's quarantine counters; see server.note_divergence)
        instead of shipping poison to the aggregator."""
        new = self._train(params, images, labels, key, epochs=int(epochs))
        if bool(self._finite_ok(new)):
            return new, True
        return params, False

    def train_cohort(self, params, images, labels, keys, epochs: int):
        """Batched local training: ONE vmapped step over the cohort axis.

        images: (C, S, ...), labels: (C, S), keys: (C,) per-worker PRNG
        keys.  Returns params stacked over the cohort axis (C, ...) --
        member i equals `train(params, images[i], labels[i], keys[i])` up
        to vmap's reduction-order jitter (pinned by tests/test_cohort.py).
        """
        return self._train_cohort(params, jnp.asarray(images),
                                  jnp.asarray(labels), keys,
                                  epochs=int(epochs))

    def train_cohort_checked(self, params, images, labels, keys, epochs: int):
        """`train_cohort` with the per-member non-finite guard: diverged
        members are replaced by the unchanged input params and flagged
        False in the returned (C,) ok vector."""
        stacked = self.train_cohort(params, images, labels, keys, epochs)
        oks = np.asarray(self._finite_members(stacked))
        if not oks.all():
            bad = ~oks
            stacked = jax.tree.map(
                lambda s, p: jnp.where(
                    jnp.asarray(bad).reshape((-1,) + (1,) * p.ndim),
                    p[None], s), stacked, params)
        return stacked, oks

    def evaluate(self, params, images, labels) -> float:
        return float(self._eval(params, images, labels))


@dataclasses.dataclass
class SimWorker:
    """One simulated worker: data shard + trainer + ground-truth profile."""
    wid: int
    images: np.ndarray
    labels: np.ndarray
    trainer: LocalTrainer
    profile: object               # WorkerProfile

    base_version: int = -1        # server version the local model is based on
    diverged: bool = False        # last local step hit the non-finite guard

    def local_train(self, params, key, epochs: int):
        if self.images.shape[0] == 0:
            return params
        new, ok = self.trainer.train_checked(
            params, jnp.asarray(self.images), jnp.asarray(self.labels),
            key, epochs)
        self.diverged = not ok
        return new
