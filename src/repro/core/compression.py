"""Weight/gradient compression for the cross-island exchange (beyond-paper
distributed-optimisation trick; the paper only notes transmission cost).

Two quantisation SCALE LAYOUTS share one symmetric-int8 core
(`_symmetric_q8`); which one applies depends on where the bytes live:

  * **blockwise** (wire format) -- flatten, pad to a multiple of `block`,
    quantise (nblocks, block) with one fp32 scale per block.  This is the
    serialised form that crosses Tier-A links (warehouse / fog uplinks):
    layout-free, so the receiver only needs `shape` to reconstruct.  The
    pad DOES cross the wire: `compressed_bytes` counts nblocks*block int8
    payload plus 4 bytes per scale.
  * **rowwise** (sharding-preserving) -- one fp32 scale per last-dim
    channel; `q` keeps the SAME shape as the input, so inside an SPMD
    program the quantised tensor inherits the input's sharding and the
    exchange never forces a reshard.  Used by
    `federated.fl_aggregate_compressed`; the TPU hot path for both
    layouts is kernels/quant8 (Pallas), this module is the jnp reference
    used everywhere else.

Top-k sparsification (`sparsify_topk` / `topk_mask`) composes with either
layout; `compress_tree(mode=...)` exposes "q8" | "topk" | "q8_topk".
`ErrorFeedback` accumulates the compression residual locally and adds it
to the next round's delta, so any of the modes is unbiased over time
(Seide et al. / EF-SGD style).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

MODES = ("q8", "topk", "q8_topk")


def _pad_to_block(flat, block):
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, n


def _symmetric_q8(x):
    """Shared scale-layout core: symmetric int8 along the LAST axis.
    x: (..., G) fp32 -> (int8 same shape, fp32 scales (..., 1))."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = amax / 127.0
    safe = jnp.maximum(scale, 1e-12)   # zero rows -> q = 0 (scale clamp)
    q = jnp.clip(jnp.round(x / safe), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


# --------------------------------------------------------------------------
# Blockwise (wire format)
# --------------------------------------------------------------------------

def quantize_blockwise(x, *, block: int = 256):
    """x: any-shape float -> (int8 (nblocks, block), fp32 scales (nblocks,))."""
    flat, _ = _pad_to_block(x.astype(jnp.float32).reshape(-1), block)
    q, scale = _symmetric_q8(flat.reshape(-1, block))
    return q, scale[:, 0]


def dequantize_blockwise(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


# --------------------------------------------------------------------------
# Rowwise (sharding-preserving, per last-dim channel)
# --------------------------------------------------------------------------

def quantize_rowwise(x):
    """x: (..., C) float -> (int8 SAME shape, fp32 scales (..., 1)).
    No flatten, no pad: q inherits x's sharding (the exchange layout)."""
    return _symmetric_q8(x.astype(jnp.float32))


def dequantize_rowwise(q, scale, *, out_dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(out_dtype)


# --------------------------------------------------------------------------
# Top-k sparsification
# --------------------------------------------------------------------------

def _k_of(n: int, k_frac: float) -> int:
    return max(1, min(n, int(math.ceil(k_frac * n))))


def sparsify_topk(x, *, k_frac: float = 0.05):
    """Keep the k = ceil(k_frac * n) largest-magnitude entries (wire form).
    Returns (idx int32 (k,), val fp32 (k,)) over the flattened x."""
    flat = x.astype(jnp.float32).reshape(-1)
    k = _k_of(flat.shape[0], k_frac)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    return idx.astype(jnp.int32), flat[idx]


def topk_mask(x, *, k_frac: float = 0.05, batch_dims: int = 0):
    """Shape/sharding-preserving top-k: a boolean mask keeping, per batch
    element (leading `batch_dims` axes), every entry whose magnitude
    reaches the k-th largest.  Ties at the threshold keep a few extra
    entries rather than gathering (no reshard inside SPMD)."""
    xf = jnp.abs(x.astype(jnp.float32))
    flat = xf.reshape(x.shape[:batch_dims] + (-1,))
    k = _k_of(flat.shape[-1], k_frac)
    kth = jax.lax.top_k(flat, k)[0][..., -1]
    kth = kth.reshape(x.shape[:batch_dims] + (1,) * (x.ndim - batch_dims))
    return xf >= jnp.maximum(kth, 1e-30)   # all-zero input keeps nothing


# --------------------------------------------------------------------------
# Tree compression (mode = "q8" | "topk" | "q8_topk")
# --------------------------------------------------------------------------

def compress_tree(tree, *, mode: str = "q8", block: int = 256,
                  k_frac: float = 0.05):
    """pytree -> pytree of wire-format dicts (leaves become dicts).

    "q8":      {"q", "scale", "shape", "dtype"}          blockwise int8
    "topk":    {"idx", "val", "shape", "dtype"}          sparse fp32
    "q8_topk": {"idx", "q", "scale", "k", "shape", "dtype"}  sparse int8
    """
    if mode not in MODES:
        raise ValueError(f"unknown compression mode '{mode}' (use {MODES})")

    def one(leaf):
        meta = {"shape": tuple(leaf.shape), "dtype": str(leaf.dtype)}
        if mode == "q8":
            q, s = quantize_blockwise(leaf, block=block)
            return {"q": q, "scale": s, **meta}
        idx, val = sparsify_topk(leaf, k_frac=k_frac)
        if mode == "topk":
            return {"idx": idx, "val": val, **meta}
        q, s = quantize_blockwise(val, block=block)
        return {"idx": idx, "q": q, "scale": s, "k": int(idx.shape[0]),
                **meta}
    return jax.tree.map(one, tree)


def _is_cleaf(x):
    return isinstance(x, dict) and ("q" in x or "val" in x)


def decompress_tree(ctree):
    def one(d):
        n = 1
        for s in d["shape"]:
            n *= s
        if "idx" in d:
            if "val" in d:                       # topk
                val = d["val"]
            else:                                # q8_topk
                val = dequantize_blockwise(d["q"], d["scale"],
                                           (d["k"],))
            x = jnp.zeros((n,), jnp.float32).at[d["idx"]].set(val)
            x = x.reshape(d["shape"])
        else:                                    # q8
            x = dequantize_blockwise(d["q"], d["scale"], d["shape"])
        return x.astype(d["dtype"])
    return jax.tree.map(one, ctree, is_leaf=_is_cleaf)


def roundtrip_islands(stacked, base, *, mode: str = "q8",
                      block: int = 256, k_frac: float = 0.05):
    """Round-trip every island's delta-from-base through the compressed
    wire: leaves are stacked (P, ...), and each island's delta is
    compressed/decompressed INDEPENDENTLY (per-island payloads -- top-k
    selection and block scales never straddle island boundaries, exactly
    like the real wire).  Returns the reconstructed stacked tree, i.e.
    base + decode(encode(member - base)) per island.

    This is what a robust aggregator must fold (and what its
    finite/quarantine gate must threshold): the DECOMPRESSED deltas are
    what actually reaches the aggregator, not the members' full-precision
    local weights (launch/train.py --robust-agg x --compress)."""
    P = jax.tree.leaves(stacked)[0].shape[0]
    outs = []
    for i in range(P):
        pi = jax.tree.map(lambda l: l[i], stacked)
        bi = jax.tree.map(lambda l: l[i], base)
        delta = jax.tree.map(
            lambda p, b: p.astype(jnp.float32) - b.astype(jnp.float32),
            pi, bi)
        delta = decompress_tree(compress_tree(delta, mode=mode,
                                              block=block, k_frac=k_frac))
        outs.append(jax.tree.map(
            lambda b, d: (b.astype(jnp.float32) + d).astype(b.dtype),
            bi, delta))
    return jax.tree.map(lambda *xs: jnp.stack(xs), *outs)


def compressed_bytes(tree, *, mode: str = "q8", block: int = 256,
                     k_frac: float = 0.05) -> int:
    """Bytes on the wire for the compressed form.  `block`/`k_frac` must
    match the `compress_tree(...)` call the wire actually uses.

    "none" counts the uncompressed storage bytes.  "q8" counts the PADDED
    int8 payload -- `quantize_blockwise` pads to a block multiple, so the
    wire carries nblocks*block + 4*nblocks bytes (an earlier version
    counted only the n unpadded bytes).  "q8_rowwise" counts the
    sharding-preserving exchange layout: n int8 + one fp32 scale per
    last-dim row.  Works on abstract leaves (anything with shape/dtype).
    """
    total = 0
    for leaf in jax.tree.leaves(tree):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        if mode == "none":
            total += n * np.dtype(leaf.dtype).itemsize
            continue
        if mode == "q8_rowwise":
            rows = n // leaf.shape[-1] if leaf.shape else 1
            total += n + 4 * rows
            continue
        nblocks = -(-n // block)
        if mode == "q8":
            total += nblocks * block + 4 * nblocks
        elif mode == "topk":
            total += 8 * _k_of(n, k_frac)            # int32 idx + fp32 val
        elif mode == "q8_topk":
            k = _k_of(n, k_frac)
            kb = -(-k // block)
            total += 4 * k + kb * block + 4 * kb     # idx + padded q8 vals
        else:
            raise ValueError(f"unknown compression mode '{mode}'")
    return total


class ErrorFeedback:
    """Stateful residual accumulator: delta_sent = C(delta + residual).
    Works for any `compress_tree` mode -- the residual carries both the
    quantisation error and the entries top-k dropped."""

    def __init__(self, like_tree):
        self.residual = jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), like_tree)

    def compress(self, delta, *, mode: str = "q8", block: int = 256,
                 k_frac: float = 0.05):
        carried = jax.tree.map(
            lambda d, r: d.astype(jnp.float32) + r, delta, self.residual)
        ctree = compress_tree(carried, mode=mode, block=block, k_frac=k_frac)
        deq = decompress_tree(jax.tree.map(
            lambda d: dict(d, dtype="float32"), ctree, is_leaf=_is_cleaf))
        self.residual = jax.tree.map(lambda c, q: c - q, carried, deq)
        return ctree
