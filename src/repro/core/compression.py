"""Weight/gradient compression for the cross-island exchange (beyond-paper
distributed-optimisation trick; the paper only notes transmission cost).

Per-block symmetric int8 quantisation with error feedback: the quantisation
residual is accumulated locally and added to the next round's delta, so the
compression is unbiased over time (Seide et al. / EF-SGD style).  The TPU
hot path is kernels/quant8 (Pallas); this module is the jnp reference used
everywhere else.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _pad_to_block(flat, block):
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, n


def quantize_blockwise(x, *, block: int = 256):
    """x: any-shape float -> (int8 (nblocks, block), fp32 scales (nblocks,))."""
    flat, _ = _pad_to_block(x.astype(jnp.float32).reshape(-1), block)
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    safe = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / safe[:, None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_blockwise(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_tree(tree, *, block: int = 256):
    """pytree -> pytree of (q8, scale) pairs (leaves become dicts)."""
    def one(leaf):
        q, s = quantize_blockwise(leaf, block=block)
        return {"q": q, "scale": s, "shape": tuple(leaf.shape),
                "dtype": str(leaf.dtype)}
    return jax.tree.map(one, tree)


def decompress_tree(ctree):
    def one(d):
        x = dequantize_blockwise(d["q"], d["scale"], d["shape"])
        return x.astype(d["dtype"])
    return jax.tree.map(one, ctree,
                        is_leaf=lambda x: isinstance(x, dict) and "q" in x)


def compressed_bytes(tree, *, block: int = 256) -> int:
    """Bytes on the wire for the compressed form (int8 + fp32 scales).
    `block` must match the `compress_tree(block=...)` the wire actually
    uses -- the count was silently hardcoded to 256 before."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        n = leaf.size
        nblocks = -(-n // block)
        total += n + 4 * nblocks
    return total


class ErrorFeedback:
    """Stateful residual accumulator: delta_sent = Q(delta + residual)."""

    def __init__(self, like_tree):
        self.residual = jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), like_tree)

    def compress(self, delta, *, block: int = 256):
        carried = jax.tree.map(
            lambda d, r: d.astype(jnp.float32) + r, delta, self.residual)
        ctree = compress_tree(carried, block=block)
        deq = decompress_tree(jax.tree.map(
            lambda d: dict(d, dtype="float32"), ctree,
            is_leaf=lambda x: isinstance(x, dict) and "q" in x))
        self.residual = jax.tree.map(lambda c, q: c - q, carried, deq)
        return ctree
