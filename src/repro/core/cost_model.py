"""System-parameter cost model (paper SSIII-D.3, Eq. 4).

The aggregation server estimates each worker's per-epoch training time from
profiled system parameters (the FogBus2 Profiler analogue):

    T_one_w = (T_onedata / CPU_s^freq) * CPU_w^freq_ratio * CPU_w^prop * N_w

where T_onedata is a server-side calibration (time to train ONE sample),
CPU ratios translate it to the worker's clock, CPU_prop accounts for
availability (contention), and N_w is the worker's sample count.  Transmit
time is estimated from a randomly-sized probe transfer (paper SSIII-D.3) --
here: model_bytes / bandwidth + latency.

Once a worker actually participates, ESTIMATES are replaced by measured
values via an EWMA (this is also the straggler detector for Tier B).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class WorkerProfile:
    """Ground-truth system parameters of one (simulated) worker."""
    wid: int
    cpu_freq: float = 2.4e9        # Hz
    cpu_prop: float = 1.0          # available fraction (>=, contention <1)
    bandwidth: float = 100e6 / 8   # bytes/s (100 Mbit)
    latency: float = 0.05          # s per message
    n_data: int = 0                # samples held locally
    speed_factor: float = 1.0      # true slowdown vs the reference machine

    def true_t_one(self, t_per_sample_ref: float) -> float:
        """True wall-clock for one local epoch over all local data."""
        return (t_per_sample_ref * self.speed_factor / max(self.cpu_prop, 1e-3)
                * self.n_data)

    def true_t_transmit(self, model_bytes: int) -> float:
        return 2.0 * (model_bytes / self.bandwidth) + self.latency


@dataclasses.dataclass
class WorkerStats:
    """The server's VIEW of a worker (estimates -> measurements)."""
    wid: int
    t_one: float                   # est. seconds / epoch (all local data)
    t_transmit: float              # est. seconds to exchange weights
    n_data: int
    last_contribution: float = 0.0  # sim-time of last accepted response
    rounds_participated: int = 0
    ewma_beta: float = 0.5

    def observe(self, t_one_measured: float, t_transmit_measured: float):
        b = self.ewma_beta
        self.t_one = (1 - b) * self.t_one + b * t_one_measured
        self.t_transmit = (1 - b) * self.t_transmit + b * t_transmit_measured
        self.rounds_participated += 1


def estimate_t_one(profile: WorkerProfile, *, t_onedata_server: float,
                   server_freq: float) -> float:
    """Eq. 4 -- the server never sees `speed_factor`; it extrapolates from
    its own calibration and the worker's advertised CPU stats."""
    per_sample = (t_onedata_server / server_freq) * profile.cpu_freq
    return per_sample / max(profile.cpu_prop, 1e-3) * profile.n_data


def estimate_t_transmit(profile: WorkerProfile, model_bytes: int) -> float:
    return 2.0 * (model_bytes / profile.bandwidth) + profile.latency


def make_stats(profile: WorkerProfile, *, t_onedata_server: float,
               server_freq: float, model_bytes: int) -> WorkerStats:
    return WorkerStats(
        wid=profile.wid,
        t_one=estimate_t_one(profile, t_onedata_server=t_onedata_server,
                             server_freq=server_freq),
        t_transmit=estimate_t_transmit(profile, model_bytes),
        n_data=profile.n_data,
    )


def heterogeneous_profiles(n_workers: int, n_data: list[int], *, seed: int = 0,
                           speed_spread: float = 4.0) -> list[WorkerProfile]:
    """Worker fleet with speeds spread uniformly in [1, speed_spread] and
    mildly varied network, mirroring the paper's VM heterogeneity."""
    rng = np.random.default_rng(seed)
    profiles = []
    for i in range(n_workers):
        speed = float(rng.uniform(1.0, speed_spread))
        profiles.append(WorkerProfile(
            wid=i,
            cpu_freq=rng.uniform(1.8e9, 3.2e9),
            cpu_prop=float(rng.uniform(0.6, 1.0)),
            bandwidth=float(rng.uniform(25e6, 200e6)) / 8,
            latency=float(rng.uniform(0.01, 0.1)),
            n_data=int(n_data[i]),
            speed_factor=speed,
        ))
    return profiles
