"""Discrete-event FL engine (Tier A -- reproduces the paper's experiments).

Simulated WALL-CLOCK comes from each worker's ground-truth profile (speed
factor, contention, bandwidth) while MODEL QUALITY comes from real JAX
training on the worker's private shard -- exactly the paper's setup, with
the VM fleet replaced by a seeded event queue.

Sync:  server selects -> all selected train r epochs -> barrier at the
       slowest finish -> weighted aggregate -> evaluate -> policy update.
Async: server folds each response the moment it arrives (staleness-weighted
       alpha), re-dispatches the worker on the NEW version, and late
       responses are still folded -- never dropped (paper SSIII-C.4 case 3).

Fault injection (core/faults.py): a `FaultPlan` corrupts worker updates on
the wire (Byzantine attacks), drops / duplicates responses, crash-restarts
workers, and kills the aggregation server mid-round -- every decision
seeded and replayable.  Rejected/diverged updates feed the server's
quarantine counters; async rejections go through the server's bounded
retry/backoff policy.

Crash-safe resume: with a `CheckpointManager` attached, the FULL simulation
state (server model + control plane, numpy/jax RNG streams, sim clock, and
for async the in-flight response heap including trained params) is
checkpointed at round granularity.  A killed run resumed from the latest
checkpoint replays the interrupted round and produces a SimRecord stream
bit-identical to an uninterrupted run (tests/test_resume.py).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

import jax
import numpy as np

from repro.core.client import SimWorker
from repro.core.server import AggregationServer


@dataclasses.dataclass
class SimRecord:
    time: float
    acc: float
    round: int
    n_selected: int
    version: int


@dataclasses.dataclass
class SimResult:
    records: list[SimRecord]
    final_params: object = None
    crashed: bool = False         # server killed mid-round (resume to finish)

    def time_to_accuracy(self, target: float) -> float:
        for r in self.records:
            if r.acc >= target:
                return r.time
        return float("inf")

    @property
    def best_acc(self) -> float:
        return max((r.acc for r in self.records), default=0.0)

    def as_arrays(self):
        t = np.array([r.time for r in self.records])
        a = np.array([r.acc for r in self.records])
        return t, a


class FLSimulation:
    def __init__(self, server: AggregationServer, workers: dict[int, SimWorker],
                 test_images, test_labels, *, t_per_sample_ref: float = 2e-3,
                 model_bytes: int = 0, round_overhead: float = 0.5,
                 idle_tick: float = 0.2, time_noise: float = 0.05,
                 seed: int = 0, cohort: bool = True, faults=None,
                 ckpt=None, ckpt_every: int = 1):
        self.server = server
        self.workers = workers
        self.test_images = test_images
        self.test_labels = test_labels
        self.t_ref = t_per_sample_ref
        self.model_bytes = model_bytes
        self.round_overhead = round_overhead
        self.idle_tick = idle_tick
        self.noise = time_noise
        self.rng = np.random.default_rng(seed + 17)
        self.key = jax.random.key(seed)
        # cohort=True trains same-shape worker groups in one vmapped step
        # (client.LocalTrainer.train_cohort) instead of a Python loop.
        self.cohort = cohort
        self.faults = faults          # Optional faults.FaultPlan
        self.ckpt = ckpt              # Optional checkpoint.CheckpointManager
        self.ckpt_every = max(int(ckpt_every), 1)
        trainer = next(iter(workers.values())).trainer
        self._eval = lambda p: trainer.evaluate(p, test_images, test_labels)

    # -- timing helpers ------------------------------------------------
    def _noisy(self, t: float) -> float:
        return float(t * self.rng.lognormal(0.0, self.noise))

    def _duration(self, w: SimWorker, epochs: int) -> tuple[float, float, float]:
        t_one = self._noisy(w.profile.true_t_one(self.t_ref))
        t_tx = self._noisy(w.profile.true_t_transmit(self.model_bytes))
        return t_one * epochs + t_tx, t_one, t_tx

    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    # -- crash-safe state ---------------------------------------------
    def _save_state(self, kind: str, step: int, t: float, last_acc: float,
                    *, heap=(), seq: int = 0, merges: int = 0,
                    rejects: Optional[dict] = None):
        if self.ckpt is None:
            return
        srv = self.server
        state = {"key": np.asarray(jax.random.key_data(self.key))}
        if srv._sopt_state.momentum is not None:
            state["sopt_m"] = srv._sopt_state.momentum
        if srv._sopt_state.variance is not None:
            state["sopt_v"] = srv._sopt_state.variance
        heap_meta = []
        for i, (t_fin, s, wid, params, base_version, dup) in \
                enumerate(sorted(heap)):
            state[f"h{i}"] = params
            heap_meta.append({"t_fin": t_fin, "seq": s, "wid": wid,
                              "base_version": base_version, "dup": dup})
        extra = {"kind": kind, "step": int(step), "t": float(t),
                 "last_acc": float(last_acc),
                 "rng_state": self.rng.bit_generator.state,
                 "server": srv.state_dict(),
                 "heap_meta": heap_meta, "seq": int(seq),
                 "merges": int(merges),
                 "rejects": {str(k): int(v)
                             for k, v in (rejects or {}).items()}}
        self.ckpt.save(step, params=srv.params, opt_state=state, extra=extra)

    def _restore_state(self, kind: str) -> dict:
        from repro.checkpoint.manager import load_pytree
        srv = self.server
        step, params, _, extra = self.ckpt.restore(params_like=srv.params)
        if extra.get("kind") != kind:
            raise ValueError(f"checkpoint at step {step} is a "
                             f"'{extra.get('kind')}' run, not '{kind}'")
        srv.params = jax.tree.map(jax.numpy.asarray, params)
        srv.load_state_dict(extra["server"])
        like = {"key": np.asarray(jax.random.key_data(self.key))}
        if srv._sopt_state.momentum is not None:
            like["sopt_m"] = srv._sopt_state.momentum
        if srv._sopt_state.variance is not None:
            like["sopt_v"] = srv._sopt_state.variance
        for i in range(len(extra["heap_meta"])):
            like[f"h{i}"] = srv.params
        state = load_pytree(self.ckpt.path_for(step) / "opt_state.npz", like)
        self.key = jax.random.wrap_key_data(
            jax.numpy.asarray(state["key"], np.uint32))
        if "sopt_m" in like:
            srv._sopt_state = dataclasses.replace(
                srv._sopt_state, momentum=state["sopt_m"])
        if "sopt_v" in like:
            srv._sopt_state = dataclasses.replace(
                srv._sopt_state, variance=state["sopt_v"])
        self.rng.bit_generator.state = extra["rng_state"]
        heap = []
        for i, m in enumerate(extra["heap_meta"]):
            p = jax.tree.map(
                lambda a, l: jax.numpy.asarray(a, l.dtype),
                state[f"h{i}"], srv.params)
            heap.append((float(m["t_fin"]), int(m["seq"]), int(m["wid"]),
                         p, int(m["base_version"]), bool(m["dup"])))
        heapq.heapify(heap)
        return {"step": step, "t": float(extra["t"]),
                "last_acc": float(extra["last_acc"]), "heap": heap,
                "seq": int(extra["seq"]), "merges": int(extra["merges"]),
                "rejects": {int(k): int(v)
                            for k, v in extra.get("rejects", {}).items()}}

    def _skip_crash_after(self, resumed_past: int) -> Optional[int]:
        """The crash that killed the run we are resuming must not re-fire
        when its round is replayed; later crash rounds still do."""
        if self.faults is None:
            return None
        pending = [int(r) for r in self.faults.cfg.server_crash_rounds
                   if int(r) > resumed_past]
        return min(pending, default=None)

    # -- cohort training ----------------------------------------------
    def _train_plan(self, params, plan: list[tuple[int, int, object]]
                    ) -> tuple[dict[int, object], list[int]]:
        """Execute [(wid, epochs, key), ...] -> ({wid: new_params},
        diverged_wids).

        Workers whose shards share a shape (and epoch count and trainer)
        train as ONE vmapped cohort step; stragglers of odd shape fall back
        to the sequential path.  Keys were drawn per-worker in plan order,
        so grouping does not perturb the RNG stream (determinism test).
        Workers whose local step went non-finite are guarded out
        (client.LocalTrainer non-finite guard) and reported instead of
        shipping poison."""
        groups: dict[tuple, list[tuple[int, object]]] = {}
        for wid, epochs, key in plan:
            w = self.workers[wid]
            gk = (id(w.trainer), w.images.shape, epochs)
            groups.setdefault(gk, []).append((wid, key))
        out: dict[int, object] = {}
        diverged: list[int] = []
        for (_, shape, epochs), members in groups.items():
            if self.cohort and len(members) > 1 and shape[0] > 0:
                from repro.core import federated
                w0 = self.workers[members[0][0]]
                shards = [(self.workers[m].images, self.workers[m].labels)
                          for m, _ in members]
                import jax.numpy as jnp
                images = jnp.stack([jnp.asarray(x) for x, _ in shards])
                labels = jnp.stack([jnp.asarray(y) for _, y in shards])
                stacked, oks = w0.trainer.train_cohort_checked(
                    params, images, labels,
                    jnp.stack([k for _, k in members]), epochs)
                for i, (m, _) in enumerate(members):
                    if bool(oks[i]):
                        out[m] = federated.island_slice(stacked, i)
                    else:
                        diverged.append(m)
            else:
                for m, key in members:
                    p = self.workers[m].local_train(params, key, epochs)
                    if getattr(self.workers[m], "diverged", False):
                        diverged.append(m)
                    else:
                        out[m] = p
        return out, diverged

    def _inject_sync(self, responses: dict[int, object], base, rnd: int
                     ) -> dict[int, object]:
        """Apply the fault plan to one sync round's responses: Byzantine
        corruption relative to the dispatch base, then drops / worker
        crashes (the sync barrier dedupes duplicates by construction)."""
        if self.faults is None:
            return responses
        out = {}
        for wid, p in responses.items():
            if self.faults.response_fate(wid, rnd) == "drop":
                continue
            out[wid] = self.faults.corrupt(p, base, wid, rnd)
        return out

    # -- synchronous ---------------------------------------------------
    def run_sync(self, rounds: int, *, max_time: float = np.inf,
                 target_acc: float = np.inf, resume: bool = False) -> SimResult:
        srv = self.server
        skip_crash = None
        if resume and self.ckpt is not None and \
                self.ckpt.latest_step() is not None:
            st = self._restore_state("sync")
            t, start, last_acc = st["t"], st["step"], st["last_acc"]
            recs: list[SimRecord] = []
            skip_crash = self._skip_crash_after(start)
        else:
            t, start = 0.0, 0
            last_acc = self._eval(srv.params)
            recs = [SimRecord(0.0, last_acc, 0, 0, 0)]
        for rnd in range(start + 1, rounds + 1):
            sel = srv.select()
            if not sel:
                t += self.idle_tick
                recs.append(SimRecord(t, last_acc, rnd, 0, srv.version))
                srv.record_accuracy(last_acc)
                if self.ckpt and rnd % self.ckpt_every == 0:
                    self._save_state("sync", rnd, t, last_acc)
                continue
            finish = 0.0
            budget = max(
                srv.stats[w].t_one * srv.epochs_for(w) + srv.stats[w].t_transmit
                for w in sel)
            plan = []
            for wid in sel:
                w = self.workers[wid]
                epochs = srv.epochs_for(wid, budget)
                dur, t_one, t_tx = self._duration(w, epochs)
                plan.append((wid, epochs, self._next_key()))
                srv.stats[wid].observe(t_one, t_tx)
                finish = max(finish, dur)
            responses, diverged = self._train_plan(srv.params, plan)
            for wid in diverged:
                srv.note_divergence(wid)
            responses = self._inject_sync(responses, srv.params, rnd)
            t += finish + self.round_overhead
            srv.sync_aggregate(responses, t)
            if self.faults is not None and self.faults.server_crashes(rnd) \
                    and rnd != skip_crash:
                # killed mid-round: the round's work is lost (no record, no
                # checkpoint); resume replays it from the last checkpoint
                return SimResult(recs, srv.params, crashed=True)
            acc = self._eval(srv.params)
            last_acc = acc
            recs.append(SimRecord(t, acc, rnd, len(sel), srv.version))
            srv.record_accuracy(acc)
            if self.ckpt and rnd % self.ckpt_every == 0:
                self._save_state("sync", rnd, t, acc)
            if acc >= target_acc or t >= max_time:
                break
        return SimResult(recs, srv.params)

    # -- asynchronous ----------------------------------------------------
    def run_async(self, max_merges: int, *, max_time: float = np.inf,
                  target_acc: float = np.inf, resume: bool = False
                  ) -> SimResult:
        srv = self.server
        heap: list = []
        rejects: dict[int, int] = {}
        skip_crash = None
        if resume and self.ckpt is not None and \
                self.ckpt.latest_step() is not None:
            st = self._restore_state("async")
            t, merges, last_acc = st["t"], st["merges"], st["last_acc"]
            heap, seq, rejects = st["heap"], st["seq"], st["rejects"]
            recs: list[SimRecord] = []
            skip_crash = self._skip_crash_after(merges)
        else:
            t, merges, seq = 0.0, 0, 0
            last_acc = self._eval(srv.params)
            recs = [SimRecord(0.0, last_acc, 0, 0, 0)]
        # a duplicate re-delivery is not an outstanding dispatch: the live
        # run never marks it in-flight, so the rebuilt set must not either
        in_flight: set[int] = {e[2] for e in heap if not e[5]}

        def dispatch(wid: int, now: float, delay: float = 0.0):
            nonlocal seq
            w = self.workers[wid]
            epochs = srv.epochs_for(wid)
            dur, t_one, t_tx = self._duration(w, epochs)
            new_params = w.local_train(srv.params, self._next_key(), epochs)
            if getattr(w, "diverged", False):
                srv.note_divergence(wid)
                return
            if self.faults is not None:
                # Byzantine corruption rides the wire; keyed by the unique
                # dispatch seq so replays inject identically
                new_params = self.faults.corrupt(new_params, srv.params,
                                                 wid, seq)
            srv.stats[wid].observe(t_one, t_tx)
            heapq.heappush(heap, (now + delay + dur, seq, wid, new_params,
                                  srv.version, False))
            seq += 1
            in_flight.add(wid)

        if not heap and not resume:
            for wid in srv.select():
                dispatch(wid, t)

        while merges < max_merges and t < max_time:
            if not heap:  # nobody selected yet (alg-2 cold start, T=0)
                t += self.idle_tick
                srv.record_accuracy(last_acc)
                recs.append(SimRecord(t, last_acc, merges, 0, srv.version))
                for wid in srv.select():
                    if wid not in in_flight:
                        dispatch(wid, t)
                continue
            t_fin, sq, wid, w_params, base_version, is_dup = \
                heapq.heappop(heap)
            in_flight.discard(wid)
            t = max(t, t_fin)
            if self.faults is not None and not is_dup:
                fate = self.faults.response_fate(wid, sq)
                if fate == "drop":
                    for w2 in srv.select():
                        if w2 not in in_flight:
                            dispatch(w2, t)
                    continue
                if fate == "duplicate":
                    # the network re-delivers the same message a beat later
                    heapq.heappush(heap, (t + self.idle_tick, seq, wid,
                                          w_params, base_version, True))
                    seq += 1
            accepted = srv.async_fold(wid, w_params, base_version, t)
            if not accepted:
                # bounded retry with exponential backoff (server policy)
                rejects[wid] = rejects.get(wid, 0) + 1
                delay = srv.retry_policy(wid, rejects[wid])
                if delay is not None and wid not in in_flight:
                    dispatch(wid, t, delay=delay)
                for w2 in srv.select():
                    if w2 not in in_flight:
                        dispatch(w2, t)
                continue
            merges += 1
            if self.faults is not None and \
                    self.faults.server_crashes(merges) and \
                    merges != skip_crash:
                return SimResult(recs, srv.params, crashed=True)
            acc = self._eval(srv.params)
            last_acc = acc
            recs.append(SimRecord(t, acc, merges, 1, srv.version))
            srv.record_accuracy(acc)
            if acc >= target_acc:
                break
            for w2 in srv.select():
                if w2 not in in_flight:
                    dispatch(w2, t)
            # checkpoint AFTER the re-dispatch: the saved heap must contain
            # the responses this merge put in flight, or a resumed run
            # would never see them
            if self.ckpt and merges % self.ckpt_every == 0:
                self._save_state("async", merges, t, acc, heap=heap,
                                 seq=seq, merges=merges, rejects=rejects)
        return SimResult(recs, srv.params)
