"""Discrete-event FL engine (Tier A -- reproduces the paper's experiments).

Simulated WALL-CLOCK comes from each worker's ground-truth profile (speed
factor, contention, bandwidth) while MODEL QUALITY comes from real JAX
training on the worker's private shard -- exactly the paper's setup, with
the VM fleet replaced by a seeded event queue.

Sync:  server selects -> all selected train r epochs -> barrier at the
       slowest finish -> weighted aggregate -> evaluate -> policy update.
Async: server folds each response the moment it arrives (staleness-weighted
       alpha), re-dispatches the worker on the NEW version, and late
       responses are still folded -- never dropped (paper SSIII-C.4 case 3).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

import jax
import numpy as np

from repro.core.client import SimWorker
from repro.core.server import AggregationServer


@dataclasses.dataclass
class SimRecord:
    time: float
    acc: float
    round: int
    n_selected: int
    version: int


@dataclasses.dataclass
class SimResult:
    records: list[SimRecord]
    final_params: object = None

    def time_to_accuracy(self, target: float) -> float:
        for r in self.records:
            if r.acc >= target:
                return r.time
        return float("inf")

    @property
    def best_acc(self) -> float:
        return max((r.acc for r in self.records), default=0.0)

    def as_arrays(self):
        t = np.array([r.time for r in self.records])
        a = np.array([r.acc for r in self.records])
        return t, a


class FLSimulation:
    def __init__(self, server: AggregationServer, workers: dict[int, SimWorker],
                 test_images, test_labels, *, t_per_sample_ref: float = 2e-3,
                 model_bytes: int = 0, round_overhead: float = 0.5,
                 idle_tick: float = 0.2, time_noise: float = 0.05,
                 seed: int = 0, cohort: bool = True):
        self.server = server
        self.workers = workers
        self.test_images = test_images
        self.test_labels = test_labels
        self.t_ref = t_per_sample_ref
        self.model_bytes = model_bytes
        self.round_overhead = round_overhead
        self.idle_tick = idle_tick
        self.noise = time_noise
        self.rng = np.random.default_rng(seed + 17)
        self.key = jax.random.key(seed)
        # cohort=True trains same-shape worker groups in one vmapped step
        # (client.LocalTrainer.train_cohort) instead of a Python loop.
        self.cohort = cohort
        trainer = next(iter(workers.values())).trainer
        self._eval = lambda p: trainer.evaluate(p, test_images, test_labels)

    # -- timing helpers ------------------------------------------------
    def _noisy(self, t: float) -> float:
        return float(t * self.rng.lognormal(0.0, self.noise))

    def _duration(self, w: SimWorker, epochs: int) -> tuple[float, float, float]:
        t_one = self._noisy(w.profile.true_t_one(self.t_ref))
        t_tx = self._noisy(w.profile.true_t_transmit(self.model_bytes))
        return t_one * epochs + t_tx, t_one, t_tx

    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    # -- cohort training ----------------------------------------------
    def _train_plan(self, params, plan: list[tuple[int, int, object]]
                    ) -> dict[int, object]:
        """Execute [(wid, epochs, key), ...] -> {wid: new_params}.

        Workers whose shards share a shape (and epoch count and trainer)
        train as ONE vmapped cohort step; stragglers of odd shape fall back
        to the sequential path.  Keys were drawn per-worker in plan order,
        so grouping does not perturb the RNG stream (determinism test)."""
        groups: dict[tuple, list[tuple[int, object]]] = {}
        for wid, epochs, key in plan:
            w = self.workers[wid]
            gk = (id(w.trainer), w.images.shape, epochs)
            groups.setdefault(gk, []).append((wid, key))
        out: dict[int, object] = {}
        for (_, shape, epochs), members in groups.items():
            if self.cohort and len(members) > 1 and shape[0] > 0:
                from repro.core import federated
                w0 = self.workers[members[0][0]]
                shards = [(self.workers[m].images, self.workers[m].labels)
                          for m, _ in members]
                stacked = federated.cohort_train(
                    w0.trainer, params, shards,
                    [k for _, k in members], epochs)
                for i, (m, _) in enumerate(members):
                    out[m] = federated.island_slice(stacked, i)
            else:
                for m, key in members:
                    out[m] = self.workers[m].local_train(params, key, epochs)
        return out

    # -- synchronous ---------------------------------------------------
    def run_sync(self, rounds: int, *, max_time: float = np.inf,
                 target_acc: float = np.inf) -> SimResult:
        srv = self.server
        t = 0.0
        recs = [SimRecord(0.0, self._eval(srv.params), 0, 0, 0)]
        for rnd in range(1, rounds + 1):
            sel = srv.select()
            if not sel:
                t += self.idle_tick
                acc = recs[-1].acc
                recs.append(SimRecord(t, acc, rnd, 0, srv.version))
                srv.record_accuracy(acc)
                continue
            finish = 0.0
            budget = max(
                srv.stats[w].t_one * srv.epochs_for(w) + srv.stats[w].t_transmit
                for w in sel)
            plan = []
            for wid in sel:
                w = self.workers[wid]
                epochs = srv.epochs_for(wid, budget)
                dur, t_one, t_tx = self._duration(w, epochs)
                plan.append((wid, epochs, self._next_key()))
                srv.stats[wid].observe(t_one, t_tx)
                finish = max(finish, dur)
            responses = self._train_plan(srv.params, plan)
            t += finish + self.round_overhead
            srv.sync_aggregate(responses, t)
            acc = self._eval(srv.params)
            recs.append(SimRecord(t, acc, rnd, len(sel), srv.version))
            srv.record_accuracy(acc)
            if acc >= target_acc or t >= max_time:
                break
        return SimResult(recs, srv.params)

    # -- asynchronous ----------------------------------------------------
    def run_async(self, max_merges: int, *, max_time: float = np.inf,
                  target_acc: float = np.inf) -> SimResult:
        srv = self.server
        t = 0.0
        recs = [SimRecord(0.0, self._eval(srv.params), 0, 0, 0)]
        heap: list = []
        seq = 0
        in_flight: set[int] = set()

        def dispatch(wid: int, now: float):
            nonlocal seq
            w = self.workers[wid]
            epochs = srv.epochs_for(wid)
            dur, t_one, t_tx = self._duration(w, epochs)
            new_params = w.local_train(srv.params, self._next_key(), epochs)
            srv.stats[wid].observe(t_one, t_tx)
            heapq.heappush(heap, (now + dur, seq, wid, new_params,
                                  srv.version))
            seq += 1
            in_flight.add(wid)

        for wid in srv.select():
            dispatch(wid, t)

        merges = 0
        while merges < max_merges and t < max_time:
            if not heap:  # nobody selected yet (alg-2 cold start, T=0)
                t += self.idle_tick
                acc = recs[-1].acc
                srv.record_accuracy(acc)
                recs.append(SimRecord(t, acc, merges, 0, srv.version))
                for wid in srv.select():
                    if wid not in in_flight:
                        dispatch(wid, t)
                continue
            t_fin, _, wid, w_params, base_version = heapq.heappop(heap)
            in_flight.discard(wid)
            t = max(t, t_fin)
            srv.async_fold(wid, w_params, base_version, t)
            merges += 1
            acc = self._eval(srv.params)
            recs.append(SimRecord(t, acc, merges, 1, srv.version))
            srv.record_accuracy(acc)
            if acc >= target_acc:
                break
            for w2 in srv.select():
                if w2 not in in_flight:
                    dispatch(w2, t)
        return SimResult(recs, srv.params)
