"""Seeded fault injection: deterministic, replayable corruption of the
federated control plane.

The scenario engines simulate only BENIGN faults (churn, stragglers);
every update that arrives is folded into the server model unexamined.
This module is the adversarial/unreliable half: a `FaultPlan` derived
from a `FaultConfig` makes every fault decision a pure function of
(seed, worker id, round), so two runs with the same plan inject
byte-identical faults regardless of call order -- the same counter-based
RNG discipline `scenarios.shard_for` uses for data shards.

Fault taxonomy (all opt-in, default rates 0):

  * BYZANTINE UPDATES -- a fixed seed-chosen subset of workers ships
    corrupted weights every time it participates:
      - ``nan`` / ``inf``  : non-finite entries sprayed into the update
      - ``sign_flip``      : w' = base - (w - base)   (reflected delta)
      - ``scale``          : w' = base + s * (w - base), s >> 1
      - ``noise``          : additive Gaussian noise on the update
      - ``stale``          : stale-base replay (resends the dispatch base,
                             i.e. zero progress dressed as a response)
  * RESPONSE FAULTS -- per (worker, round): drop (message lost) or
    duplicate (message folded twice; async engines re-deliver).
  * WORKER CRASH -- per (worker, round): the worker dies mid-round and
    restarts; its response for the round is lost.
  * SERVER CRASH -- at configured rounds the aggregation server process
    is killed mid-round (engines return SimResult(crashed=True) and are
    expected to resume from the last checkpoint).

Defenses live elsewhere: `aggregation.robust_aggregate*` (trimmed mean /
median / multi-Krum / norm clipping), the server's sanitization gate
(`server.AggregationServer`), and round-granular checkpointing in the
engines.  This module only BREAKS things, deterministically.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

ATTACKS = ("nan", "inf", "sign_flip", "scale", "noise", "stale")

# domain-separation constants for the counter-based draws
_BYZ, _ATK, _FATE, _CRASH, _NOISE = 9176, 4391, 5281, 6733, 8269


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Rates and shapes of the injected faults (all per response/round)."""
    byzantine_frac: float = 0.0          # fixed fraction of Byzantine workers
    attacks: tuple = ("sign_flip", "scale")   # pool Byzantine workers draw from
    scale_factor: float = 10.0           # blow-up for the "scale" attack
    noise_std: float = 1.0               # std for the "noise" attack
    nonfinite_frac: float = 0.01         # entry fraction hit by nan/inf
    drop_frac: float = 0.0               # P(response lost) per round
    duplicate_frac: float = 0.0          # P(response delivered twice)
    worker_crash_frac: float = 0.0       # P(worker crash-restarts) per round
    server_crash_rounds: tuple = ()      # rounds where the server is killed
    seed: int = 0


class FaultPlan:
    """Deterministic fault schedule.  Every method is a pure function of
    the config seed and its arguments -- replayable, order-independent."""

    def __init__(self, cfg: FaultConfig):
        for a in cfg.attacks:
            if a not in ATTACKS:
                raise ValueError(f"unknown attack '{a}' (have {ATTACKS})")
        self.cfg = cfg

    # -- decision draws (counter-based, order-independent) -----------------
    def _rng(self, *key: int) -> np.random.Generator:
        return np.random.default_rng((self.cfg.seed,) + tuple(
            int(k) for k in key))

    def is_byzantine(self, wid: int) -> bool:
        c = self.cfg
        if c.byzantine_frac <= 0:
            return False
        return bool(self._rng(_BYZ, wid).random() < c.byzantine_frac)

    def attack_for(self, wid: int) -> str:
        atk = self.cfg.attacks
        return atk[int(self._rng(_ATK, wid).integers(len(atk)))]

    def response_fate(self, wid: int, rnd: int) -> str:
        """'deliver' | 'drop' | 'duplicate' for this worker's response.
        A worker crash also loses the response ('drop', crash flavor)."""
        c = self.cfg
        if c.worker_crash_frac > 0 and \
                self._rng(_CRASH, wid, rnd).random() < c.worker_crash_frac:
            return "drop"
        u = self._rng(_FATE, wid, rnd).random()
        if u < c.drop_frac:
            return "drop"
        if u < c.drop_frac + c.duplicate_frac:
            return "duplicate"
        return "deliver"

    def server_crashes(self, rnd: int) -> bool:
        return int(rnd) in set(int(r) for r in self.cfg.server_crash_rounds)

    # -- update corruption -------------------------------------------------
    def corrupt(self, params, base, wid: int, rnd: int):
        """Byzantine-corrupt one response (pytree) relative to the model
        `base` it was trained from.  Identity for honest workers."""
        if not self.is_byzantine(wid):
            return params
        attack = self.attack_for(wid)
        c = self.cfg

        if attack == "stale":
            return jax.tree.map(lambda b, p: jnp.asarray(b, p.dtype),
                                base, params)

        def one(p, b, leaf_i):
            p32 = jnp.asarray(p, jnp.float32)
            b32 = jnp.asarray(b, jnp.float32)
            if attack == "sign_flip":
                out = b32 - (p32 - b32)
            elif attack == "scale":
                out = b32 + c.scale_factor * (p32 - b32)
            elif attack == "noise":
                rng = self._rng(_NOISE, wid, rnd, leaf_i)
                out = p32 + jnp.asarray(
                    rng.normal(0.0, c.noise_std, p.shape), jnp.float32)
            elif attack in ("nan", "inf"):
                rng = self._rng(_NOISE, wid, rnd, leaf_i)
                mask = rng.random(p.shape) < c.nonfinite_frac
                mask.flat[0] = True          # at least one poisoned entry
                bad = jnp.float32(jnp.nan if attack == "nan" else jnp.inf)
                out = jnp.where(jnp.asarray(mask), bad, p32)
            else:  # pragma: no cover -- attacks validated in __init__
                raise ValueError(attack)
            return out.astype(p.dtype)

        leaves, treedef = jax.tree.flatten(params)
        bleaves = jax.tree.leaves(base)
        return jax.tree.unflatten(
            treedef, [one(p, b, i) for i, (p, b)
                      in enumerate(zip(leaves, bleaves))])

    def corrupt_stacked(self, stacked, base, wids: Sequence[int], rnd: int):
        """Corrupt members of a stacked (C, ...) cohort tree in place of
        their leading-axis slices.  `base` is the shared dispatch model
        (unstacked).  Honest members pass through untouched."""
        for i, wid in enumerate(wids):
            if not self.is_byzantine(int(wid)):
                continue
            sub = jax.tree.map(lambda x: x[i], stacked)
            sub = self.corrupt(sub, base, int(wid), rnd)
            stacked = jax.tree.map(lambda s, c: s.at[i].set(c), stacked, sub)
        return stacked

    # -- bookkeeping -------------------------------------------------------
    def byzantine_in(self, wids: Sequence[int]) -> list[int]:
        return [int(w) for w in wids if self.is_byzantine(int(w))]


def finite_members(stacked) -> np.ndarray:
    """(C,) bool: member i's slice has only finite entries in every leaf.
    The stacked-engine half of the server's sanitization gate."""
    ok = None
    for leaf in jax.tree.leaves(stacked):
        axes = tuple(range(1, leaf.ndim))
        l_ok = np.asarray(jnp.all(jnp.isfinite(
            jnp.asarray(leaf, jnp.float32)), axis=axes))
        ok = l_ok if ok is None else (ok & l_ok)
    return ok if ok is not None else np.zeros(0, bool)
