"""Tier B: the paper's FL technique as SPMD collectives over the `pod` axis.

Each pod is one federated island (cross-silo FL).  Islands run E local SPMD
steps (FSDP x TP inside the island), then exchange weights through ONE
mixing collective:

    new_params_i = sum_j M[i, j] * params_j        (M: island mixing matrix)

M encodes the whole FLight control plane -- worker selection (zeroed
columns), FedAvg weighting (data-proportional rows), and async staleness
mixes (diagonal + rank-1) -- as RUNTIME INPUTS, so selection decisions never
trigger recompilation.  The collective moves param-shard bytes over the pod
axis: this is the paper's 'FTP bulk channel', ridden on ICI/DCN.

Island-distinct parameters are expressed with a leading `island` axis
sharded over "pod"; the island-local train step is vmapped over it with
spmd_axis_name="pod" (see launch/train.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, compression


@dataclasses.dataclass(frozen=True)
class FLConfig:
    n_islands: int = 1
    local_steps: int = 8           # E: train steps between aggregations
    aggregation: str = "fedavg"
    mode: str = "sync"             # sync | async
    async_base_alpha: float = 0.6
    staleness_scheme: str = "polynomial"
    compress: str = "none"         # exchange compression:
    #                                none | q8 | topk | q8_topk
    topk_frac: float = 0.05        # kept fraction for the topk modes
    overlap: bool = False          # double-buffer exchange w/ local steps


def stack_islands(tree, n_islands: int):
    """Tile a single-island pytree into (n_islands, ...) leaves."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_islands,) + x.shape), tree)


def island_slice(tree, i: int):
    return jax.tree.map(lambda x: x[i], tree)


def cohort_train(trainer, params, shards, keys, epochs: int):
    """Train a whole cohort in ONE batched step instead of Python-looping
    `local_train`: stack the worker shards along a leading cohort axis and
    vmap the island-local trainer over it (`params` broadcast, exactly the
    `stack_islands` layout).  Returns params stacked (C, ...) -- feed
    straight into `fl_aggregate` / `hierarchy.hierarchical_sync_aggregate`.

    shards: sequence of (images, labels) with EQUAL shapes (the caller
    groups by shape; see events.FLSimulation._train_plan)."""
    images = jnp.stack([jnp.asarray(x) for x, _ in shards])
    labels = jnp.stack([jnp.asarray(y) for _, y in shards])
    return trainer.train_cohort(params, images, labels, jnp.stack(keys),
                                epochs)


def fl_aggregate(stacked_params, mixing):
    """The FLight exchange: one mixing collective over the island axis.
    stacked_params: pytree with leading island axis sharded over "pod";
    mixing: (P, P) runtime array (selection/weights/staleness encoded)."""
    return aggregation.mix_islands(stacked_params, mixing)


def _resolve_impl(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


def fl_aggregate_compressed(stacked_params, base_params, mixing, *,
                            mode: str = "q8", k_frac: float = 0.05,
                            impl: str = "auto"):
    """Beyond-paper: exchange compressed DELTAS from the shared last-sync
    base instead of raw weights, in ONE jitted step:
    (sparsify ->) quantize -> mixing collective -> dequantize.

    Every island already holds `base_params` (the previous exchange's
    result), so only the compressed delta crosses the pod axis: int8 +
    per-channel scales for "q8" (~4x fewer wire bytes than f32, and
    immune to the CPU backend's bf16->f32 collective legalisation -- int8
    stays int8), optionally top-k sparsified first ("topk" keeps fp32
    values, "q8_topk" stacks both).  Requires row-stochastic mixing
    (sum_j M[i,j] = 1), which all FLight mixes satisfy.

    Per-channel (last-dim) scales keep q the SAME shape/sharding as the
    leaf -- flattening would force a cross-axis reshard (a first
    formulation gathered over every mesh axis; see SSPerf).  The top-k
    stage is the threshold-mask form (compression.topk_mask) for the same
    reason: a gather of the survivors would reshard.

    impl="auto" quantises through the fused kernels/quant8 Pallas pass on
    TPU and falls back to the jnp reference (core.compression, same
    rounding) elsewhere; dequantisation stays jnp so XLA fuses it into
    the mixing contraction."""
    if mode == "none":
        return fl_aggregate(stacked_params, mixing)
    if mode not in compression.MODES:
        raise ValueError(f"unknown exchange compression mode '{mode}'")
    use_pallas = _resolve_impl(impl) == "pallas"

    def mix(leaf, b):
        delta = (leaf.astype(jnp.float32) - b.astype(jnp.float32))
        if mode in ("topk", "q8_topk"):
            # per-island top-k over the leaf (batch dim = island axis)
            mask = compression.topk_mask(delta, k_frac=k_frac,
                                         batch_dims=1)
            delta = jnp.where(mask, delta, 0.0)
        if mode in ("q8", "q8_topk"):
            if use_pallas:
                from repro.kernels.quant8 import ops as q8ops
                q, scale = q8ops.quantize_rowwise(delta)
            else:
                q, scale = compression.quantize_rowwise(delta)
            delta = q.astype(jnp.float32) * scale
        mixed = jnp.tensordot(mixing.astype(jnp.float32), delta, axes=1)
        return (b.astype(jnp.float32) + mixed).astype(leaf.dtype)

    return jax.tree.map(mix, stacked_params, base_params)


def fl_aggregate_robust(stacked_params, method: str, *, base_params=None,
                        **kw):
    """Byzantine-robust exchange: every island receives the robust fold of
    all island models (trimmed mean / median / multi-Krum / norm clipping,
    see aggregation.ROBUST_METHODS) instead of the mixing-matrix weighted
    average.  Unlike `fl_aggregate` this is NOT expressible as a
    row-stochastic mixing matrix -- robustness is exactly the refusal to
    take fixed linear combinations an attacker could dominate."""
    agg = aggregation.robust_aggregate_stacked(stacked_params, method,
                                               base=base_params, **kw)
    return jax.tree.map(
        lambda a, s: jnp.broadcast_to(a.astype(s.dtype)[None],
                                      s.shape), agg, stacked_params)


def fl_overlap_merge(params, mixed, snapshot):
    """Re-apply the local progress made WHILE the exchange was in flight.

    With the double-buffered exchange (launch/train.py --overlap) the
    mixing collective for round r runs concurrently with the first local
    step of round r+1, which therefore starts from the pre-exchange
    snapshot.  When the collective lands, the exchange correction
    (mixed - snapshot) is added on top of the current params -- the local
    step is never recomputed, the exchange is one step stale."""
    def one(p, m, s):
        out = (p.astype(jnp.float32) + m.astype(jnp.float32)
               - s.astype(jnp.float32))
        return out.astype(p.dtype)
    return jax.tree.map(one, params, mixed, snapshot)


def selection_mixing(weights: np.ndarray, selected: np.ndarray) -> np.ndarray:
    """Sync FedAvg restricted to selected islands; unselected islands still
    RECEIVE the aggregate (they re-sync, matching the paper's workers that
    download the latest server model when next contacted)."""
    w = np.asarray(weights, np.float64) * np.asarray(selected, np.float64)
    if w.sum() <= 0:
        return np.eye(len(w))
    w = w / w.sum()
    return aggregation.sync_mixing_matrix(w)


def async_mixing(alphas, contributors) -> np.ndarray:
    return aggregation.async_mixing_matrix(np.asarray(alphas),
                                           np.asarray(contributors))


@dataclasses.dataclass
class IslandClock:
    """Host-side straggler monitor: EWMA step-times per island (the Tier-B
    analogue of the FogBus2 profiler feeding Algorithm 2)."""
    n_islands: int
    beta: float = 0.3
    ewma: Optional[np.ndarray] = None

    def observe(self, step_times: np.ndarray):
        t = np.asarray(step_times, np.float64)
        self.ewma = t if self.ewma is None else \
            (1 - self.beta) * self.ewma + self.beta * t

    def selection(self, slack: float = 1.5) -> np.ndarray:
        """Islands slower than `slack` x median are dropped this round
        (Algorithm 2's T-threshold with T = slack * median estimate)."""
        if self.ewma is None:
            return np.ones(self.n_islands)
        med = np.median(self.ewma)
        return (self.ewma <= slack * med).astype(np.float64)
