"""Two-tier edge -> fog -> cloud aggregation (the fog-computing topology).

FLight's setting puts an aggregation layer BETWEEN the edge workers and the
cloud server: workers report to their fog cell (a gateway/proxy close to
them), each cell folds its members with the usual weighted mean, and the
cloud folds the (much fewer) cell aggregates.  Because weighted averaging
is associative over a partition of the weights, the composition is EXACTLY
the flat aggregate for matching weights:

    cloud( fog_c( {x_j : j in c} ) )  ==  sum_j (w_j / W) x_j

for every partition {c} of the workers -- the equivalence this module is
pinned to by tests/test_hierarchy.py (sync FedAvg and the async
staleness-weighted fold alike).  That identity is what makes the fog tier a
pure SCALING move: each cell only touches its members, the cloud only
touches cells, and no tier ever materialises the full worker fan-in.

Two call surfaces:
  * dict-level (Tier A, the discrete-event simulator): worker-id keyed
    responses -> `fog_aggregate_responses`.
  * stacked/matrix-level (Tier B and the scenario engine): a pytree with a
    leading island axis plus mixing matrices built here, folded with the
    existing `federated.fl_aggregate` -- the edge stage is a block-diagonal
    mixing matrix, the cloud stage a rank-structured one, and their product
    equals the flat mixing matrix.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation


# --------------------------------------------------------------------------
# Topology
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FogTopology:
    """Assignment of worker ids to fog cells (cell ids are arbitrary ints)."""
    cell_of: Mapping[int, int]

    @classmethod
    def round_robin(cls, worker_ids: Iterable[int], n_cells: int
                    ) -> "FogTopology":
        ids = sorted(worker_ids)
        n_cells = max(1, int(n_cells))
        return cls({w: i % n_cells for i, w in enumerate(ids)})

    @classmethod
    def random(cls, worker_ids: Iterable[int], n_cells: int, *, seed: int = 0
               ) -> "FogTopology":
        ids = sorted(worker_ids)
        rng = np.random.default_rng(seed)
        return cls({w: int(c) for w, c in
                    zip(ids, rng.integers(0, max(1, int(n_cells)), len(ids)))})

    @property
    def n_cells(self) -> int:
        return len(set(self.cell_of.values()))

    def cells(self) -> dict[int, list[int]]:
        """cell id -> sorted member worker ids."""
        out: dict[int, list[int]] = {}
        for w in sorted(self.cell_of):
            out.setdefault(self.cell_of[w], []).append(w)
        return out

    def restrict(self, worker_ids: Iterable[int]) -> "FogTopology":
        """Topology induced on a subset (e.g. this round's selected set)."""
        keep = set(worker_ids)
        return FogTopology({w: c for w, c in self.cell_of.items()
                            if w in keep})


# --------------------------------------------------------------------------
# Dict-level (Tier A): responses keyed by worker id
# --------------------------------------------------------------------------

def fog_aggregate_responses(responses: Mapping[int, object],
                            weights: Mapping[int, float],
                            topology: FogTopology, *,
                            robust: str | None = None,
                            robust_kw: Mapping | None = None):
    """Edge->fog->cloud weighted mean of `responses`.

    Each fog cell averages its members with within-cell normalised weights;
    the cloud averages the cell aggregates weighted by each cell's weight
    MASS.  Equals the flat weighted average of all responses (the
    associativity identity in the module docstring).

    With `robust` set (see aggregation.ROBUST_METHODS) each fog cell folds
    its members with the robust aggregator instead -- a Byzantine worker
    can then poison at most its own cell's aggregate, and the cloud fold
    over the (much fewer) cell aggregates runs the SAME robust method, so
    even a fully captured cell is trimmed/outvoted at the top.  Weighted
    exactness is deliberately given up: robust statistics are unweighted
    (see aggregation.robust_aggregate_stacked)."""
    cells = topology.restrict(responses).cells()
    if not cells:
        raise ValueError("no responses to aggregate")
    kw = dict(robust_kw or {})
    cell_params, cell_mass = [], []
    for members in cells.values():
        w = np.array([max(float(weights[m]), 0.0) for m in members])
        mass = float(w.sum())
        wn = w / mass if mass > 0 else np.full(len(w), 1.0 / len(w))
        member_params = [responses[m] for m in members]
        if robust:
            cell_params.append(
                aggregation.robust_aggregate(member_params, robust, **kw))
        else:
            cell_params.append(
                aggregation.weighted_average(member_params, wn))
        cell_mass.append(mass if mass > 0 else 0.0)
    if robust and len(cell_params) > 1:
        return aggregation.robust_aggregate(cell_params, robust, **kw)
    mass = np.asarray(cell_mass)
    mn = mass / mass.sum() if mass.sum() > 0 else \
        np.full(len(mass), 1.0 / len(mass))
    return aggregation.weighted_average(cell_params, mn)


def hierarchical_robust_aggregate(stacked_params, cell_of: Sequence[int],
                                  method: str, *, base=None, **kw):
    """Robust edge->fog->cloud fold of a stacked (P, ...) member tree into
    ONE aggregate: each cell robust-folds its member slices, the cloud
    robust-folds the cell aggregates (same method).  The stacked-engine
    sibling of `fog_aggregate_responses(robust=...)`."""
    cells = _cells_from_array(cell_of)
    cell_aggs = []
    for members in cells.values():
        sub = jax.tree.map(lambda x: jnp.asarray(x)[np.asarray(members)],
                           stacked_params)
        cell_aggs.append(aggregation.robust_aggregate_stacked(
            sub, method, base=base, **kw))
    if len(cell_aggs) == 1:
        return cell_aggs[0]
    stacked_cells = jax.tree.map(lambda *ls: jnp.stack(ls), *cell_aggs)
    return aggregation.robust_aggregate_stacked(stacked_cells, method,
                                                base=base, **kw)


# --------------------------------------------------------------------------
# Matrix-level (Tier B / scenario engine): compose with fl_aggregate
# --------------------------------------------------------------------------

def _cells_from_array(cell_of: Sequence[int]) -> dict[int, np.ndarray]:
    c = np.asarray(cell_of, int)
    return {int(k): np.flatnonzero(c == k) for k in np.unique(c)}

def _norm_or_uniform(w: np.ndarray) -> np.ndarray:
    s = w.sum()
    return w / s if s > 0 else np.full(len(w), 1.0 / len(w))


def edge_mixing_matrix(weights: Sequence[float], cell_of: Sequence[int]
                       ) -> np.ndarray:
    """Fog stage: island i receives its OWN cell's weighted mean.

    Block-diagonal row-stochastic (P, P); applying it with `fl_aggregate`
    leaves every member of a cell holding that cell's aggregate."""
    w = np.maximum(np.asarray(weights, np.float64), 0.0)
    M = np.zeros((len(w), len(w)))
    for members in _cells_from_array(cell_of).values():
        M[np.ix_(members, members)] = _norm_or_uniform(w[members])[None, :]
    return M


def cloud_mixing_matrix(weights: Sequence[float], cell_of: Sequence[int]
                        ) -> np.ndarray:
    """Cloud stage AFTER the edge stage: every island receives the
    cell-mass-weighted mean of the cell aggregates.  Each cell's aggregate
    is read off its first member (any member would do -- rows within a cell
    are equal after `edge_mixing_matrix`)."""
    w = np.maximum(np.asarray(weights, np.float64), 0.0)
    cells = _cells_from_array(cell_of)
    mass = np.array([w[m].sum() for m in cells.values()])
    mn = _norm_or_uniform(mass)
    M = np.zeros((len(w), len(w)))
    for mi, members in zip(mn, cells.values()):
        M[:, members[0]] = mi
    return M


def flat_mixing_matrix(weights: Sequence[float]) -> np.ndarray:
    """The single-tier reference: every island gets the global mean."""
    w = np.maximum(np.asarray(weights, np.float64), 0.0)
    return aggregation.sync_mixing_matrix(_norm_or_uniform(w))


def hierarchical_sync_aggregate(stacked_params, weights: Sequence[float],
                                cell_of: Sequence[int], *,
                                compress: str = "none",
                                base_params=None,
                                k_frac: float = 0.05):
    """Two `fl_aggregate` hops (edge then cloud) over the island axis.

    cloud_mixing_matrix @ edge_mixing_matrix == flat_mixing_matrix, so this
    equals the flat exchange -- but no single mixing ever has fan-in wider
    than max(cell size, n_cells).

    With compress != "none" both hops run the compressed delta exchange
    (`federated.fl_aggregate_compressed`, modes q8/topk/q8_topk) against
    the shared last-sync `base_params`: the edge mixing is block-diagonal,
    so the first compressed collective stays CELL-LOCAL (only the narrow
    cell->cloud hop spans cells), matching the fog-tier byte budget the
    paper's transmission-cost analysis targets.  Equals the flat
    compressed exchange up to one extra quantisation of the fog-stage
    deltas (bounded by the per-row scale; see tests/test_hierarchy.py)."""
    from repro.core.federated import fl_aggregate, fl_aggregate_compressed
    edge_M = jnp.asarray(edge_mixing_matrix(weights, cell_of), jnp.float32)
    cloud_M = jnp.asarray(cloud_mixing_matrix(weights, cell_of), jnp.float32)
    if compress in (None, False, "none"):
        fog = fl_aggregate(stacked_params, edge_M)
        return fl_aggregate(fog, cloud_M)
    if base_params is None:
        raise ValueError("compressed hierarchical exchange needs the "
                         "shared last-sync base_params")
    fog = fl_aggregate_compressed(stacked_params, base_params, edge_M,
                                  mode=compress, k_frac=k_frac)
    return fl_aggregate_compressed(fog, base_params, cloud_M,
                                   mode=compress, k_frac=k_frac)


def hierarchical_async_aggregate(stacked_params, alphas: Sequence[float],
                                 contributors: Sequence[float],
                                 cell_of: Sequence[int]):
    """Staleness-weighted async fold through the fog tier.

    Flat reference: `fl_aggregate(x, async_mixing_matrix(a, c))`, i.e.
    island i keeps (1 - a_i) of itself plus a_i of the contributor mix.
    Here the contributor mix is built hierarchically -- cells aggregate
    their contributors, the cloud mixes cells by contribution mass -- and
    the final convex combination with each island's own params is
    elementwise.  Identical to the flat fold (tests pin <= 1e-5)."""
    from repro.core.federated import fl_aggregate
    c = np.maximum(np.asarray(contributors, np.float64), 0.0)
    fog = fl_aggregate(stacked_params,
                       jnp.asarray(edge_mixing_matrix(c, cell_of),
                                   jnp.float32))
    mix = fl_aggregate(fog, jnp.asarray(cloud_mixing_matrix(c, cell_of),
                                        jnp.float32))
    a = np.asarray(alphas, np.float64)

    def combine(x, m):
        av = jnp.asarray(a, jnp.float32).reshape((-1,) + (1,) * (x.ndim - 1))
        out = (1.0 - av) * x.astype(jnp.float32) + av * m.astype(jnp.float32)
        return out.astype(x.dtype)

    return jax.tree.map(combine, stacked_params, mix)
