"""Block-table paged KV-cache bookkeeping (vLLM-style, host side).

The serve tier used to give every slot a private contiguous cache of
``max_len`` positions: concurrency was capped at ``max_batch`` and a
12-token prompt paid for 512 slots of HBM.  Here the cache is a single
pool of fixed-size BLOCKS; each sequence owns a *block table* (list of
block ids), positions map to ``(table[pos // block_size], pos % block_size)``,
and blocks are handed out lazily as decode crosses block boundaries.

Prefix sharing: the KV contents of a block holding positions
``[i*bs, (i+1)*bs)`` depend only on the prompt prefix ``tokens[:(i+1)*bs]``
(causal attention), so full prompt blocks are registered under that exact
prefix (the token tuple itself -- no hash collisions) and later requests
with the same prefix re-use them with a refcount instead of recomputing
prefill for those positions.  Only *full* blocks are ever shared; the
tail block of a prompt is always private because decode writes into it.
Registered blocks whose refcount drops to zero stay warm in an LRU until
pool pressure evicts them.

This module is pure host-side bookkeeping (python ints and lists); the
device-side gather/scatter that consumes the block tables lives in
``repro.models.layers`` (paged_attention_*) and the serve loop in
``repro.launch.serve_loop`` (PagedServeLoop).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Optional, Sequence


class OutOfBlocks(RuntimeError):
    """Raised when an allocation cannot be satisfied even after evicting
    every unreferenced cached block.  Callers (the serve loop) respond by
    delaying admission or preempting a live sequence."""


@dataclasses.dataclass
class AdmitResult:
    table: list                 # block ids covering the prompt
    n_shared_blocks: int        # leading blocks re-used from the prefix cache
    block_size: int

    @property
    def n_shared_tokens(self) -> int:
        return self.n_shared_blocks * self.block_size


class BlockAllocator:
    """Fixed pool of ``num_blocks`` KV blocks of ``block_size`` positions.

    Every block is in exactly ONE of three states at all times:
      * free      -- on the free list, contents meaningless;
      * active    -- referenced by >= 1 live sequence (refcount > 0);
      * cached    -- refcount == 0 but registered in the prefix cache
                     (evictable LRU, reusable by a future admit).
    ``check_invariants()`` asserts this partition; the property tests in
    tests/test_paging.py drive it through randomized admit/extend/finish
    sequences.
    """

    def __init__(self, num_blocks: int, block_size: int):
        assert num_blocks > 0 and block_size > 0
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.free: list[int] = list(range(num_blocks))
        self.ref = [0] * num_blocks
        self.block_key: list[Optional[tuple]] = [None] * num_blocks
        self.cached: dict[tuple, int] = {}         # prefix key -> block id
        self.evictable: OrderedDict[int, None] = OrderedDict()  # ref==0 cached
        self.tables: dict[int, list[int]] = {}     # seq_id -> block table
        self.stats = {"shared_blocks": 0, "evictions": 0, "allocs": 0}

    # -- low-level ------------------------------------------------------
    def n_free(self) -> int:
        """Blocks obtainable without touching active sequences."""
        return len(self.free) + len(self.evictable)

    def _take_block(self) -> int:
        if self.free:
            b = self.free.pop()
        elif self.evictable:
            b, _ = self.evictable.popitem(last=False)   # LRU eviction
            del self.cached[self.block_key[b]]
            self.block_key[b] = None
            self.stats["evictions"] += 1
        else:
            raise OutOfBlocks(
                f"no free blocks (pool={self.num_blocks}, all active)")
        self.ref[b] = 1
        self.stats["allocs"] += 1
        return b

    def _ref_block(self, b: int) -> None:
        if self.ref[b] == 0:
            self.evictable.pop(b)     # was cached; now active again
        self.ref[b] += 1

    def _unref_block(self, b: int) -> None:
        assert self.ref[b] > 0, f"double free of block {b}"
        self.ref[b] -= 1
        if self.ref[b] == 0:
            if self.block_key[b] is not None:
                self.evictable[b] = None       # stays warm in prefix cache
            else:
                self.free.append(b)

    # -- sequence lifecycle --------------------------------------------
    def admit(self, seq_id: int, tokens: Sequence[int],
              reserve: int = 1) -> AdmitResult:
        """Build a block table covering ``tokens`` (+ ``reserve`` decode
        positions), sharing leading full blocks with the prefix cache.

        The last prompt token is never covered by a shared block (its
        logits must be computed to emit the first generated token), so at
        most ``(len(tokens)-1) // block_size`` blocks are shared.
        Raises OutOfBlocks (with no state change) when the pool cannot
        cover the private remainder.
        """
        assert seq_id not in self.tables, f"seq {seq_id} already admitted"
        bs = self.block_size
        T = len(tokens)
        assert T > 0
        need_total = (T + reserve + bs - 1) // bs
        key_tokens = tuple(int(t) for t in tokens)

        shared: list[int] = []
        for i in range((T - 1) // bs):
            key = key_tokens[: (i + 1) * bs]
            b = self.cached.get(key)
            if b is None:
                break
            shared.append(b)
        n_private = need_total - len(shared)
        # blocks we are about to re-reference no longer count as reclaimable
        avail = self.n_free() - sum(1 for b in shared if b in self.evictable)
        if n_private > avail:
            raise OutOfBlocks(
                f"need {n_private} blocks for seq {seq_id}, "
                f"have {avail} reclaimable")

        for b in shared:
            self._ref_block(b)
        table = shared + [self._take_block() for _ in range(n_private)]
        self.tables[seq_id] = table
        self.stats["shared_blocks"] += len(shared)
        # register this prompt's full PRIVATE blocks for future sharing
        # (their KV is written by prefill and never touched again: decode
        # writes start at position T, i.e. in block T//bs or later)
        for i in range(len(shared), T // bs):
            key = key_tokens[: (i + 1) * bs]
            if key not in self.cached:
                self.cached[key] = table[i]
                self.block_key[table[i]] = key
        return AdmitResult(list(table), len(shared), bs)

    def ensure_capacity(self, seq_id: int, pos: int) -> bool:
        """Grow seq's table so position ``pos`` is addressable.  Returns
        True when the table changed.  Raises OutOfBlocks when the pool is
        exhausted (caller preempts)."""
        table = self.tables[seq_id]
        grew = False
        while pos // self.block_size >= len(table):
            table.append(self._take_block())
            grew = True
        return grew

    def finish(self, seq_id: int) -> None:
        """Release seq's references; cached blocks stay warm, private
        blocks return to the free list."""
        for b in self.tables.pop(seq_id):
            self._unref_block(b)

    def table(self, seq_id: int) -> list[int]:
        return list(self.tables[seq_id])

    # -- invariants -----------------------------------------------------
    def check_invariants(self) -> None:
        free = set(self.free)
        cached0 = set(self.evictable)
        active = {b for b in range(self.num_blocks) if self.ref[b] > 0}
        assert not (free & cached0), "block both free and cached"
        assert not (free & active), "block both free and active"
        assert not (cached0 & active), "block both cached-idle and active"
        assert len(free) + len(cached0) + len(active) == self.num_blocks, (
            f"pool leak: {len(free)} free + {len(cached0)} cached + "
            f"{len(active)} active != {self.num_blocks}")
        # refcount == number of live tables containing the block
        counts = [0] * self.num_blocks
        for table in self.tables.values():
            seen = set()
            for b in table:
                assert b not in seen, "block repeated within one table"
                seen.add(b)
                counts[b] += 1
        assert counts == self.ref, (
            "refcounts diverge from table membership: "
            f"{[(b, self.ref[b], counts[b]) for b in range(self.num_blocks) if self.ref[b] != counts[b]]}")
        # every cached key points at a block that remembers the key
        for key, b in self.cached.items():
            assert self.block_key[b] == key
        # a block shared by 2+ tables must be registered (full prefix)
        for b in range(self.num_blocks):
            if counts[b] > 1:
                assert self.block_key[b] is not None, (
                    f"unregistered block {b} shared by {counts[b]} tables")
