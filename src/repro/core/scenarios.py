"""Scalable FL scenario engine: churn / stragglers / non-IID drift /
partial participation at 10^5+ simulated workers.

The discrete-event engine (`core/events.py`) instantiates a SimWorker per
worker and trains each one -- faithful, but capped at a few dozen workers.
This engine splits the two things a federated simulation must produce:

  * TIMING runs over the FULL population as vectorized numpy: per-worker
    ground-truth times are arrays, a sync round is one masked max (the
    straggler barrier), async is a finish-time heap seeded with the whole
    participating set.  10^5 workers is a few array ops per round.
  * QUALITY comes from really training a SAMPLED COHORT with the batched
    vmap step (`client.LocalTrainer.train_cohort`) on freshly drawn
    non-IID shards, folded through the edge->fog->cloud hierarchy
    (`core.hierarchy`).  The cohort stands in for the round's selected set
    the way a survey samples a population.

Every random draw comes from seeded generators (numpy for the population,
a split jax key chain for training), so two runs with the same config
produce IDENTICAL SimRecord sequences -- pinned by tests/test_scenarios.py.
"""
from __future__ import annotations

import dataclasses
import heapq

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, federated, hierarchy
from repro.core.client import LocalTrainer
from repro.core.events import SimRecord, SimResult
from repro.models import build_model
from repro.models.config import ModelConfig

_DEFAULT_MODEL = ModelConfig(name="scenario-mlp", family="cnn", num_layers=0,
                             d_model=48, img_hw=28, img_c=1, n_classes=10,
                             remat=False)


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    """Knobs for one scenario.  All rates are per round (sync) or per
    cohort-generation (async)."""
    n_workers: int = 1000
    cohort_size: int = 16          # workers actually trained per round
    fog_cells: int = 4             # edge->fog->cloud cells over the cohort
    participation: float = 0.1     # fraction of ALIVE workers selected
    churn_leave: float = 0.0       # P(online worker drops) per round
    churn_join: float = 0.0        # P(offline worker rejoins) per round
    straggler_frac: float = 0.0    # fraction with a heavy-tail slowdown
    straggler_slow: float = 8.0    # their multiplicative slowdown
    drift: float = 0.0             # label-skew rotation speed (classes/round)
    dirichlet_alpha: float = 100.0  # >=100 => IID; small => label-skewed
    epochs: int = 1
    samples_per_worker: int = 64
    batch_size: int = 32
    t_per_sample: float = 2e-3     # reference seconds per sample per epoch
    round_overhead: float = 0.5
    idle_tick: float = 0.2
    async_base_alpha: float = 0.6
    staleness_scheme: str = "polynomial"
    seed: int = 0


class ScenarioSim:
    """Population-scale FL simulation (see module docstring).

    run_sync / run_async mirror events.FLSimulation's API and return the
    same SimResult record stream."""

    def __init__(self, cfg: ScenarioConfig, *, model_cfg: ModelConfig = None,
                 pool: int = 4096, eval_n: int = 512):
        from repro.data.synthetic import make_classification_set
        self.cfg = cfg
        self.model = build_model(model_cfg or _DEFAULT_MODEL)
        self.trainer = LocalTrainer(self.model, lr=0.05,
                                    batch_size=cfg.batch_size)
        self.pool_x, self.pool_y = make_classification_set(
            "synmnist", pool, seed=cfg.seed + 1)
        self.test_x, self.test_y = make_classification_set(
            "synmnist", eval_n, seed=cfg.seed + 2)
        self.n_classes = int(self.pool_y.max()) + 1
        self._class_idx = [np.flatnonzero(self.pool_y == c)
                           for c in range(self.n_classes)]

        # -- full-population ground truth (vectorized) -------------------
        n = cfg.n_workers
        rng = np.random.default_rng(cfg.seed + 23)
        speed = rng.lognormal(0.0, 0.25, n)
        slow = np.where(rng.random(n) < cfg.straggler_frac,
                        cfg.straggler_slow, 1.0)
        self.t_one = cfg.t_per_sample * cfg.samples_per_worker * speed * slow
        self.t_tx = rng.uniform(0.05, 0.3, n)
        self.alive = np.ones(n, bool)
        self.rng = np.random.default_rng(cfg.seed)     # selection + churn
        self.key = jax.random.key(cfg.seed)

    # -- helpers -----------------------------------------------------------
    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    def _churn(self):
        c = self.cfg
        if c.churn_leave > 0:
            self.alive &= ~(self.rng.random(len(self.alive)) < c.churn_leave)
        if c.churn_join > 0:
            joins = self.rng.random(len(self.alive)) < c.churn_join
            self.alive |= joins

    def _select(self) -> np.ndarray:
        alive_idx = np.flatnonzero(self.alive)
        if alive_idx.size == 0:
            return alive_idx
        n_sel = max(1, int(round(self.cfg.participation * alive_idx.size)))
        return np.sort(self.rng.choice(alive_idx, n_sel, replace=False))

    def _label_props(self, wid: int) -> np.ndarray:
        if self.cfg.dirichlet_alpha >= 100.0:
            return np.full(self.n_classes, 1.0 / self.n_classes)
        rw = np.random.default_rng((self.cfg.seed, 104729, int(wid)))
        return rw.dirichlet([self.cfg.dirichlet_alpha] * self.n_classes)

    def shard_for(self, wid: int, rnd: int):
        """Worker `wid`'s private shard at round `rnd`: label proportions
        are a per-worker Dirichlet draw rotated by the drift schedule, so a
        non-stationary fleet keeps re-skewing as the simulation advances."""
        shift = int(self.cfg.drift * rnd) % self.n_classes
        props = np.roll(self._label_props(wid), shift)
        rs = np.random.default_rng((self.cfg.seed, 7919, int(wid), shift))
        counts = rs.multinomial(self.cfg.samples_per_worker, props)
        idx = np.concatenate([
            rs.choice(self._class_idx[c], k, replace=True)
            for c, k in enumerate(counts) if k > 0])
        rs.shuffle(idx)
        return self.pool_x[idx], self.pool_y[idx]

    def _train_cohort(self, params, cohort: np.ndarray, rnd: int):
        """One vmapped batched step over the sampled cohort, folded
        edge->fog->cloud.  Returns the new global params."""
        shards = [self.shard_for(int(w), rnd) for w in cohort]
        keys = [self._next_key() for _ in cohort]
        stacked = federated.cohort_train(self.trainer, params, shards, keys,
                                         self.cfg.epochs)
        weights = np.full(len(cohort), float(self.cfg.samples_per_worker))
        cell_of = np.asarray(cohort) % max(1, self.cfg.fog_cells)
        folded = hierarchy.hierarchical_sync_aggregate(stacked, weights,
                                                       cell_of)
        return federated.island_slice(folded, 0)

    def _eval(self, params) -> float:
        return self.trainer.evaluate(params, self.test_x, self.test_y)

    # -- synchronous -------------------------------------------------------
    def run_sync(self, rounds: int, *, max_time: float = np.inf) -> SimResult:
        c = self.cfg
        params = self.model.init(jax.random.key(c.seed))
        t = 0.0
        recs = [SimRecord(0.0, self._eval(params), 0, 0, 0)]
        version = 0
        for rnd in range(1, rounds + 1):
            self._churn()
            sel = self._select()
            if sel.size == 0:
                t += c.idle_tick
                recs.append(SimRecord(t, recs[-1].acc, rnd, 0, version))
                continue
            # straggler barrier over the FULL selected set (vectorized)
            t += float((self.t_one[sel] * c.epochs + self.t_tx[sel]).max()) \
                + c.round_overhead
            cohort = np.sort(self.rng.choice(
                sel, min(c.cohort_size, sel.size), replace=False))
            params = self._train_cohort(params, cohort, rnd)
            version += 1
            recs.append(SimRecord(t, self._eval(params), rnd, int(sel.size),
                                  version))
            if t >= max_time:
                break
        return SimResult(recs, params)

    # -- asynchronous ------------------------------------------------------
    def run_async(self, max_merges: int, *, max_time: float = np.inf
                  ) -> SimResult:
        c = self.cfg
        params = self.model.init(jax.random.key(c.seed))
        t = 0.0
        recs = [SimRecord(0.0, self._eval(params), 0, 0, 0)]
        version = 0

        sel = self._select()
        if sel.size == 0:
            return SimResult(recs, params)
        finish = t + self.t_one[sel] * c.epochs + self.t_tx[sel]
        heap = [(float(f), i, int(w)) for i, (f, w) in
                enumerate(zip(finish, sel))]
        heapq.heapify(heap)
        seq = len(heap)

        # quality: a trained generation of cohort members, folded one per
        # merge with staleness-decayed alpha (the events.py async semantics
        # at population scale)
        member_queue: list = []
        base_version = 0

        def refill(rnd: int):
            nonlocal member_queue, base_version
            alive_idx = np.flatnonzero(self.alive)
            if alive_idx.size == 0:
                return
            cohort = np.sort(self.rng.choice(
                alive_idx, min(c.cohort_size, alive_idx.size), replace=False))
            shards = [self.shard_for(int(w), rnd) for w in cohort]
            keys = [self._next_key() for _ in cohort]
            stacked = federated.cohort_train(self.trainer, params, shards,
                                             keys, c.epochs)
            member_queue = [federated.island_slice(stacked, i)
                            for i in range(len(cohort))]
            base_version = version

        merges = 0
        while merges < max_merges and t < max_time and heap:
            t_fin, _, wid = heapq.heappop(heap)
            t = max(t, t_fin)
            if not member_queue:
                self._churn()
                refill(merges)
                if not member_queue:
                    t += c.idle_tick
                    continue
            w_params = member_queue.pop(0)
            alpha = aggregation.staleness_alpha(
                c.async_base_alpha, version - base_version,
                scheme=c.staleness_scheme)
            params = aggregation.async_merge(params, w_params, alpha)
            version += 1
            merges += 1
            recs.append(SimRecord(t, self._eval(params), merges, 1, version))
            if self.alive[wid]:
                heapq.heappush(
                    heap, (t + float(self.t_one[wid] * c.epochs
                                     + self.t_tx[wid]), seq, wid))
                seq += 1
        return SimResult(recs, params)
