"""Scalable FL scenario engine: churn / stragglers / non-IID drift /
partial participation at 10^5+ simulated workers.

The discrete-event engine (`core/events.py`) instantiates a SimWorker per
worker and trains each one -- faithful, but capped at a few dozen workers.
This engine splits the two things a federated simulation must produce:

  * TIMING runs over the FULL population as vectorized numpy: per-worker
    ground-truth times are arrays, a sync round is one masked max (the
    straggler barrier), async is a finish-time heap seeded with the whole
    participating set.  10^5 workers is a few array ops per round.
  * QUALITY comes from really training a SAMPLED COHORT with the batched
    vmap step (`client.LocalTrainer.train_cohort`) on freshly drawn
    non-IID shards, folded through the edge->fog->cloud hierarchy
    (`core.hierarchy`).  The cohort stands in for the round's selected set
    the way a survey samples a population.

Adversarial faults ride the same cohort path: with `byzantine_frac` set, a
seeded `faults.FaultPlan` corrupts the Byzantine members' slices of the
stacked cohort tree before the fold; non-finite members are rejected by
the sanitization scan (quarantine counters in `self.quarantine`), and
`robust_agg` swaps the weighted hierarchical fold for the Byzantine-robust
one (`hierarchy.hierarchical_robust_aggregate`).  `server_crash_round`
kills the run mid-round (SimResult.crashed) -- with a CheckpointManager
attached, `run_sync/run_async(resume=True)` continues from the last
round-granular checkpoint with a bit-identical SimRecord stream.

Every random draw comes from seeded generators (numpy for the population,
a split jax key chain for training), so two runs with the same config
produce IDENTICAL SimRecord sequences -- pinned by tests/test_scenarios.py.
"""
from __future__ import annotations

import dataclasses
import heapq

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, federated, hierarchy
from repro.core import faults as faults_mod
from repro.core.client import LocalTrainer
from repro.core.events import SimRecord, SimResult
from repro.models import build_model
from repro.models.config import ModelConfig

_DEFAULT_MODEL = ModelConfig(name="scenario-mlp", family="cnn", num_layers=0,
                             d_model=48, img_hw=28, img_c=1, n_classes=10,
                             remat=False)


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    """Knobs for one scenario.  All rates are per round (sync) or per
    cohort-generation (async)."""
    n_workers: int = 1000
    cohort_size: int = 16          # workers actually trained per round
    fog_cells: int = 4             # edge->fog->cloud cells over the cohort
    participation: float = 0.1     # fraction of ALIVE workers selected
    churn_leave: float = 0.0       # P(online worker drops) per round
    churn_join: float = 0.0        # P(offline worker rejoins) per round
    straggler_frac: float = 0.0    # fraction with a heavy-tail slowdown
    straggler_slow: float = 8.0    # their multiplicative slowdown
    drift: float = 0.0             # label-skew rotation speed (classes/round)
    dirichlet_alpha: float = 100.0  # >=100 => IID; small => label-skewed
    epochs: int = 1
    samples_per_worker: int = 64
    batch_size: int = 32
    t_per_sample: float = 2e-3     # reference seconds per sample per epoch
    round_overhead: float = 0.5
    idle_tick: float = 0.2
    async_base_alpha: float = 0.6
    staleness_scheme: str = "polynomial"
    # -- faults + defenses (core/faults.py, aggregation.ROBUST_METHODS) --
    byzantine_frac: float = 0.0    # seed-chosen fraction of corrupt workers
    byzantine_attacks: tuple = ("sign_flip", "scale")
    byzantine_scale: float = 10.0  # blow-up for the "scale" attack
    robust_agg: str = "none"       # none | trimmed_mean | median | krum |
    #                                norm_clip (hierarchical robust fold)
    trim_frac: float = 0.2         # trimmed_mean: trim ceil(frac*P)/side
    server_crash_round: int = 0    # kill the server at this round/merge
    #                                (0 = never; resume via checkpoints)
    seed: int = 0


class ScenarioSim:
    """Population-scale FL simulation (see module docstring).

    run_sync / run_async mirror events.FLSimulation's API and return the
    same SimResult record stream."""

    def __init__(self, cfg: ScenarioConfig, *, model_cfg: ModelConfig = None,
                 pool: int = 4096, eval_n: int = 512, ckpt=None,
                 ckpt_every: int = 1):
        from repro.data.synthetic import make_classification_set
        self.cfg = cfg
        self.model = build_model(model_cfg or _DEFAULT_MODEL)
        self.trainer = LocalTrainer(self.model, lr=0.05,
                                    batch_size=cfg.batch_size)
        self.pool_x, self.pool_y = make_classification_set(
            "synmnist", pool, seed=cfg.seed + 1)
        self.test_x, self.test_y = make_classification_set(
            "synmnist", eval_n, seed=cfg.seed + 2)
        self.n_classes = int(self.pool_y.max()) + 1
        self._class_idx = [np.flatnonzero(self.pool_y == c)
                           for c in range(self.n_classes)]
        if cfg.robust_agg not in ("none",) + aggregation.ROBUST_METHODS:
            raise ValueError(f"unknown robust_agg '{cfg.robust_agg}'")
        if cfg.byzantine_frac > 0 or cfg.server_crash_round > 0:
            self.faults = faults_mod.FaultPlan(faults_mod.FaultConfig(
                byzantine_frac=cfg.byzantine_frac,
                attacks=tuple(cfg.byzantine_attacks),
                scale_factor=cfg.byzantine_scale,
                server_crash_rounds=(cfg.server_crash_round,)
                if cfg.server_crash_round > 0 else (),
                seed=cfg.seed))
        else:
            self.faults = None
        self.quarantine: dict[int, int] = {}  # wid -> rejected updates
        self.ckpt = ckpt               # Optional checkpoint.CheckpointManager
        self.ckpt_every = max(int(ckpt_every), 1)

        # -- full-population ground truth (vectorized) -------------------
        n = cfg.n_workers
        rng = np.random.default_rng(cfg.seed + 23)
        speed = rng.lognormal(0.0, 0.25, n)
        slow = np.where(rng.random(n) < cfg.straggler_frac,
                        cfg.straggler_slow, 1.0)
        self.t_one = cfg.t_per_sample * cfg.samples_per_worker * speed * slow
        self.t_tx = rng.uniform(0.05, 0.3, n)
        self.alive = np.ones(n, bool)
        self.rng = np.random.default_rng(cfg.seed)     # selection + churn
        self.key = jax.random.key(cfg.seed)

    # -- helpers -----------------------------------------------------------
    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    def _churn(self):
        c = self.cfg
        if c.churn_leave > 0:
            self.alive &= ~(self.rng.random(len(self.alive)) < c.churn_leave)
        if c.churn_join > 0:
            joins = self.rng.random(len(self.alive)) < c.churn_join
            self.alive |= joins

    def _select(self) -> np.ndarray:
        alive_idx = np.flatnonzero(self.alive)
        if alive_idx.size == 0:
            return alive_idx
        n_sel = max(1, int(round(self.cfg.participation * alive_idx.size)))
        return np.sort(self.rng.choice(alive_idx, n_sel, replace=False))

    def _label_props(self, wid: int) -> np.ndarray:
        if self.cfg.dirichlet_alpha >= 100.0:
            return np.full(self.n_classes, 1.0 / self.n_classes)
        rw = np.random.default_rng((self.cfg.seed, 104729, int(wid)))
        return rw.dirichlet([self.cfg.dirichlet_alpha] * self.n_classes)

    def shard_for(self, wid: int, rnd: int):
        """Worker `wid`'s private shard at round `rnd`: label proportions
        are a per-worker Dirichlet draw rotated by the drift schedule, so a
        non-stationary fleet keeps re-skewing as the simulation advances."""
        shift = int(self.cfg.drift * rnd) % self.n_classes
        props = np.roll(self._label_props(wid), shift)
        rs = np.random.default_rng((self.cfg.seed, 7919, int(wid), shift))
        counts = rs.multinomial(self.cfg.samples_per_worker, props)
        idx = np.concatenate([
            rs.choice(self._class_idx[c], k, replace=True)
            for c, k in enumerate(counts) if k > 0])
        rs.shuffle(idx)
        return self.pool_x[idx], self.pool_y[idx]

    # -- fault injection + sanitization + fold -----------------------------
    def _inject_and_sanitize(self, params, stacked, cohort: np.ndarray,
                             rnd: int):
        """Corrupt the Byzantine members' slices, then reject (drop +
        quarantine-count) any member whose slice went non-finite.  Returns
        (stacked, cohort) restricted to the surviving members -- possibly
        empty."""
        stacked = self.faults.corrupt_stacked(stacked, params, cohort, rnd)
        ok = faults_mod.finite_members(stacked)
        if ok.all():
            return stacked, cohort
        for w in cohort[~ok]:
            self.quarantine[int(w)] = self.quarantine.get(int(w), 0) + 1
        keep = np.flatnonzero(ok)
        if keep.size == 0:
            return None, cohort[:0]
        return (jax.tree.map(lambda l: jnp.asarray(l)[keep], stacked),
                cohort[keep])

    def _fold_cohort(self, params, stacked, cohort: np.ndarray):
        """Fold the surviving cohort edge->fog->cloud: the robust fold
        when `robust_agg` is set (unweighted -- see
        aggregation.robust_aggregate_stacked), the exact weighted
        hierarchy otherwise."""
        c = self.cfg
        cell_of = np.asarray(cohort) % max(1, c.fog_cells)
        if c.robust_agg != "none":
            folded = hierarchy.hierarchical_robust_aggregate(
                stacked, cell_of, c.robust_agg, base=params,
                trim_frac=c.trim_frac)
            return jax.tree.map(lambda a, p: jnp.asarray(a, p.dtype),
                                folded, params)
        weights = np.full(len(cohort), float(c.samples_per_worker))
        folded = hierarchy.hierarchical_sync_aggregate(stacked, weights,
                                                       cell_of)
        return federated.island_slice(folded, 0)

    def _train_cohort(self, params, cohort: np.ndarray, rnd: int):
        """One vmapped batched step over the sampled cohort, folded
        edge->fog->cloud.  Returns the new global params."""
        shards = [self.shard_for(int(w), rnd) for w in cohort]
        keys = [self._next_key() for _ in cohort]
        stacked = federated.cohort_train(self.trainer, params, shards, keys,
                                         self.cfg.epochs)
        if self.faults is not None:
            stacked, cohort = self._inject_and_sanitize(params, stacked,
                                                        cohort, rnd)
            if stacked is None:      # whole cohort rejected: no progress
                return params
        return self._fold_cohort(params, stacked, cohort)

    def _eval(self, params) -> float:
        return self.trainer.evaluate(params, self.test_x, self.test_y)

    # -- crash-safe state --------------------------------------------------
    def _save_state(self, kind: str, step: int, t: float, last_acc: float,
                    params, version: int, *, heap=(), members=(),
                    base_version: int = 0, seq: int = 0, merges: int = 0):
        if self.ckpt is None:
            return
        state = {"key": np.asarray(jax.random.key_data(self.key)),
                 "alive": self.alive}
        for i, m in enumerate(members):
            state[f"m{i}"] = m
        extra = {"kind": kind, "step": int(step), "t": float(t),
                 "last_acc": float(last_acc), "version": int(version),
                 "rng_state": self.rng.bit_generator.state,
                 "quarantine": {str(k): int(v)
                                for k, v in self.quarantine.items()},
                 "heap": [[float(f), int(s), int(w)]
                          for f, s, w in sorted(heap)],
                 "n_members": len(members), "base_version": int(base_version),
                 "seq": int(seq), "merges": int(merges)}
        self.ckpt.save(step, params=params, opt_state=state, extra=extra)

    def _restore_state(self, kind: str) -> dict:
        from repro.checkpoint.manager import load_pytree
        template = self.model.init(jax.random.key(self.cfg.seed))
        step, params, _, extra = self.ckpt.restore(params_like=template)
        if extra.get("kind") != kind:
            raise ValueError(f"checkpoint at step {step} is a "
                             f"'{extra.get('kind')}' run, not '{kind}'")
        params = jax.tree.map(jnp.asarray, params)
        n_members = int(extra.get("n_members", 0))
        like = {"key": np.asarray(jax.random.key_data(self.key)),
                "alive": self.alive}
        for i in range(n_members):
            like[f"m{i}"] = template
        state = load_pytree(self.ckpt.path_for(step) / "opt_state.npz", like)
        self.key = jax.random.wrap_key_data(
            jnp.asarray(state["key"], np.uint32))
        self.alive = np.asarray(state["alive"], bool)
        self.rng.bit_generator.state = extra["rng_state"]
        self.quarantine = {int(k): int(v) for k, v in
                           extra.get("quarantine", {}).items()}
        members = [jax.tree.map(lambda a, l: jnp.asarray(a, l.dtype),
                                state[f"m{i}"], template)
                   for i in range(n_members)]
        heap = [(float(f), int(s), int(w))
                for f, s, w in extra.get("heap", [])]
        heapq.heapify(heap)
        return {"step": step, "params": params, "t": float(extra["t"]),
                "last_acc": float(extra["last_acc"]),
                "version": int(extra["version"]), "heap": heap,
                "members": members,
                "base_version": int(extra["base_version"]),
                "seq": int(extra["seq"]), "merges": int(extra["merges"])}

    def _crashes(self, rnd: int, skip: int) -> bool:
        return self.faults is not None and self.faults.server_crashes(rnd) \
            and rnd != skip

    # -- synchronous -------------------------------------------------------
    def run_sync(self, rounds: int, *, max_time: float = np.inf,
                 resume: bool = False) -> SimResult:
        c = self.cfg
        skip_crash = -1
        if resume and self.ckpt is not None and \
                self.ckpt.latest_step() is not None:
            st = self._restore_state("scen_sync")
            params, t, start = st["params"], st["t"], st["step"]
            version, last_acc = st["version"], st["last_acc"]
            recs: list[SimRecord] = []
            if c.server_crash_round > start:
                skip_crash = c.server_crash_round  # the crash that killed us
        else:
            params = self.model.init(jax.random.key(c.seed))
            t, start, version = 0.0, 0, 0
            last_acc = self._eval(params)
            recs = [SimRecord(0.0, last_acc, 0, 0, 0)]
        for rnd in range(start + 1, rounds + 1):
            self._churn()
            sel = self._select()
            if sel.size == 0:
                t += c.idle_tick
                recs.append(SimRecord(t, last_acc, rnd, 0, version))
                if self.ckpt and rnd % self.ckpt_every == 0:
                    self._save_state("scen_sync", rnd, t, last_acc, params,
                                     version)
                continue
            # straggler barrier over the FULL selected set (vectorized)
            t += float((self.t_one[sel] * c.epochs + self.t_tx[sel]).max()) \
                + c.round_overhead
            cohort = np.sort(self.rng.choice(
                sel, min(c.cohort_size, sel.size), replace=False))
            params = self._train_cohort(params, cohort, rnd)
            version += 1
            if self._crashes(rnd, skip_crash):
                # killed mid-round: the round is lost (no record, no
                # checkpoint); resume replays it from the last checkpoint
                return SimResult(recs, params, crashed=True)
            last_acc = self._eval(params)
            recs.append(SimRecord(t, last_acc, rnd, int(sel.size), version))
            if self.ckpt and rnd % self.ckpt_every == 0:
                self._save_state("scen_sync", rnd, t, last_acc, params,
                                 version)
            if t >= max_time:
                break
        return SimResult(recs, params)

    # -- asynchronous ------------------------------------------------------
    def run_async(self, max_merges: int, *, max_time: float = np.inf,
                  resume: bool = False) -> SimResult:
        c = self.cfg
        skip_crash = -1
        if resume and self.ckpt is not None and \
                self.ckpt.latest_step() is not None:
            st = self._restore_state("scen_async")
            params, t, merges = st["params"], st["t"], st["merges"]
            version, last_acc = st["version"], st["last_acc"]
            heap, seq = st["heap"], st["seq"]
            member_queue, base_version = st["members"], st["base_version"]
            recs: list[SimRecord] = []
            if c.server_crash_round > merges:
                skip_crash = c.server_crash_round
        else:
            params = self.model.init(jax.random.key(c.seed))
            t, merges, version = 0.0, 0, 0
            last_acc = self._eval(params)
            recs = [SimRecord(0.0, last_acc, 0, 0, 0)]
            sel = self._select()
            if sel.size == 0:
                return SimResult(recs, params)
            finish = t + self.t_one[sel] * c.epochs + self.t_tx[sel]
            heap = [(float(f), i, int(w)) for i, (f, w) in
                    enumerate(zip(finish, sel))]
            heapq.heapify(heap)
            seq = len(heap)
            # quality: a trained generation of cohort members, folded one
            # per merge with staleness-decayed alpha (the events.py async
            # semantics at population scale)
            member_queue = []
            base_version = 0

        def refill(rnd: int):
            nonlocal member_queue, base_version
            alive_idx = np.flatnonzero(self.alive)
            if alive_idx.size == 0:
                return
            cohort = np.sort(self.rng.choice(
                alive_idx, min(c.cohort_size, alive_idx.size), replace=False))
            shards = [self.shard_for(int(w), rnd) for w in cohort]
            keys = [self._next_key() for _ in cohort]
            stacked = federated.cohort_train(self.trainer, params, shards,
                                             keys, c.epochs)
            if self.faults is not None:
                stacked, cohort = self._inject_and_sanitize(
                    params, stacked, cohort, rnd)
                if stacked is None:
                    member_queue = []
                    return
            member_queue = [federated.island_slice(stacked, i)
                            for i in range(len(cohort))]
            base_version = version

        while merges < max_merges and t < max_time and heap:
            t_fin, _, wid = heapq.heappop(heap)
            t = max(t, t_fin)
            if not member_queue:
                self._churn()
                refill(merges)
                if not member_queue:
                    t += c.idle_tick
                    continue
            w_params = member_queue.pop(0)
            alpha = aggregation.staleness_alpha(
                c.async_base_alpha, version - base_version,
                scheme=c.staleness_scheme)
            params = aggregation.async_merge(params, w_params, alpha)
            version += 1
            merges += 1
            if self._crashes(merges, skip_crash):
                return SimResult(recs, params, crashed=True)
            last_acc = self._eval(params)
            recs.append(SimRecord(t, last_acc, merges, 1, version))
            if self.alive[wid]:
                heapq.heappush(
                    heap, (t + float(self.t_one[wid] * c.epochs
                                     + self.t_tx[wid]), seq, wid))
                seq += 1
            if self.ckpt and merges % self.ckpt_every == 0:
                self._save_state("scen_async", merges, t, last_acc, params,
                                 version, heap=heap, members=member_queue,
                                 base_version=base_version, seq=seq,
                                 merges=merges)
        return SimResult(recs, params)
