"""Worker-selection policies (paper SSIII-D).

Algorithm 1 (R-min/R-max):  select w iff finishing its MINIMUM training
  (rmin epochs + transmit) takes no longer than the fastest worker finishing
  its MAXIMUM training (rmax epochs + transmit).  NOTE: line 11 of the
  paper's listing prints `>=`, which would select only the SLOWEST workers
  and contradicts the prose ("if a worker requires more time to train a
  minimum number of epochs compared to the worker that can finish the
  maximum number ... it is excluded"); we implement the prose (`<=`).
  Eq. 1/2 as printed are likewise swapped w.r.t. the prose (rmin must DROP
  when accuracy grows); we implement the prose and verify the paper's
  divergence pathology in benchmarks/fig15-16.

Algorithm 2 (training-time-based):  select w iff T_one_w*r + T_transmit_w
  <= T; grow T to the cheapest not-yet-selected worker's total time only
  when the round-over-round accuracy gain falls below threshold A (Eq. 3).

  Knob -> paper symbol map (Algorithm 2 / Eq. 3):
    TimeBasedState.T        T      time allowed for one round (init 0: the
                                   first update admits the single cheapest
                                   worker, exactly the paper's bootstrap)
    TimeBasedState.r        r      unified local epochs per round
    TimeBasedState.A        A      accuracy-improvement threshold; a round
                                   gaining less than A triggers Eq. 3
    TimeBasedState.acc_prev acc_1  previous round's global accuracy
                                   (acc_2 is the `acc_now` argument)
    WorkerStats.t_one       T_one      one local epoch's training time
    WorkerStats.t_transmit  T_transmit model up/down transfer time
    _total_time(s, r)       T_total    = T_one * r + T_transmit
  `time_based_select` is Algorithm 2 lines 2-6 (the admission filter);
  `time_based_update` is lines 7-12 / Eq. 3 (the growth rule), with T
  monotone non-decreasing (see its docstring for the divergence the
  literal reading causes).

Plus baselines: all / random / sequential (the paper's comparison lines).
All policies are pure functions of WorkerStats -> deterministic + testable.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from repro.core.cost_model import WorkerStats


# --------------------------------------------------------------------------
# Algorithm 1: R-min / R-max
# --------------------------------------------------------------------------

@dataclasses.dataclass
class RMinRMaxState:
    rmin: float
    rmax: float
    acc_prev: float = 0.0


def rmin_rmax_select(stats: Mapping[int, WorkerStats],
                     state: RMinRMaxState) -> list[int]:
    if not stats:
        return []
    t_min = {w: s.t_one * state.rmin + s.t_transmit for w, s in stats.items()}
    t_max = {w: s.t_one * state.rmax + s.t_transmit for w, s in stats.items()}
    t_minimum = min(t_max.values())
    sel = [w for w in stats if t_min[w] <= t_minimum]
    return sorted(sel)


def rmin_rmax_update(state: RMinRMaxState, acc_now: float) -> RMinRMaxState:
    """Eq. 1/2 (prose direction): accuracy growth shrinks rmin, grows rmax.
    Accuracies are fractions in [0,1]; the +1 damping is the paper's guard
    against early-training surges."""
    ratio = (state.acc_prev + 1.0) / (acc_now + 1.0)
    rmin = max(1.0, state.rmin * ratio)
    rmax = max(rmin, state.rmax / ratio)
    return RMinRMaxState(rmin=rmin, rmax=rmax, acc_prev=acc_now)


def epochs_for_worker(stats: WorkerStats, state: RMinRMaxState,
                      budget: float) -> int:
    """Fast workers train extra epochs (up to rmax) within the round budget."""
    if stats.t_one <= 0:
        return int(round(state.rmax))
    r = int((budget - stats.t_transmit) / stats.t_one)
    return int(np.clip(r, max(1, round(state.rmin)), max(1, round(state.rmax))))


# --------------------------------------------------------------------------
# Algorithm 2: training-time-based
# --------------------------------------------------------------------------

@dataclasses.dataclass
class TimeBasedState:
    T: float = 0.0            # time allowed for a round (init 0 per paper)
    r: int = 2                # unified local epochs per round
    A: float = 0.005          # accuracy-improvement threshold (fraction)
    acc_prev: float = 0.0


def _total_time(s: WorkerStats, r: int) -> float:
    return s.t_one * r + s.t_transmit


def time_based_select(stats: Mapping[int, WorkerStats],
                      state: TimeBasedState) -> list[int]:
    sel = [w for w, s in stats.items() if _total_time(s, state.r) <= state.T]
    return sorted(sel)


def time_based_update(stats: Mapping[int, WorkerStats],
                      state: TimeBasedState, acc_now: float) -> TimeBasedState:
    """Eq. 3: admit the cheapest unselected worker when accuracy stalls.

    T is MONOTONE non-decreasing (the paper: 'more workers are included
    ... achieved by increasing the time limit').  Without the max(), a
    worker whose MEASURED time drifts above the fixed T drops back out and
    the pool oscillates at 3-4 workers instead of growing (observed;
    EXPERIMENTS.md SSPaper-validation)."""
    new = dataclasses.replace(state, acc_prev=acc_now)
    if acc_now - state.acc_prev < state.A:
        selected = set(time_based_select(stats, state))
        unselected = [s for w, s in stats.items() if w not in selected]
        if unselected:
            new = dataclasses.replace(
                new, T=max(state.T,
                           min(_total_time(s, state.r) for s in unselected)))
    return new


# --------------------------------------------------------------------------
# Baselines
# --------------------------------------------------------------------------

def select_all(stats: Mapping[int, WorkerStats]) -> list[int]:
    return sorted(stats)


def select_random(stats: Mapping[int, WorkerStats], k: int,
                  rng: np.random.Generator) -> list[int]:
    ids = sorted(stats)
    k = min(k, len(ids))
    return sorted(rng.choice(ids, size=k, replace=False).tolist())


def select_fastest(stats: Mapping[int, WorkerStats], k: int,
                   r: int = 1) -> list[int]:
    """Power-of-choice style latency-greedy baseline (beyond-paper)."""
    ranked = sorted(stats.values(), key=lambda s: _total_time(s, r))
    return sorted(s.wid for s in ranked[:k])


def select_utility(stats: Mapping[int, WorkerStats], k: int, *,
                   utilities: Mapping[int, float], r: int = 2,
                   explore: float = 0.1,
                   rng: np.random.Generator | None = None) -> list[int]:
    """Oort-style utility selection (beyond-paper): rank workers by
    statistical utility (e.g. recent local loss x sqrt(data)) divided by
    their round time, with an epsilon of random exploration so slow/unseen
    workers are still sampled.  Degrades to select_fastest when utilities
    are uniform."""
    ids = sorted(stats)
    if not ids:
        return []
    k = min(k, len(ids))
    rng = rng or np.random.default_rng(0)
    score = {
        w: (utilities.get(w, 1.0) * np.sqrt(max(stats[w].n_data, 1))
            / max(_total_time(stats[w], r), 1e-6))
        for w in ids
    }
    ranked = sorted(ids, key=lambda w: -score[w])
    n_exploit = max(1, int(round(k * (1 - explore))))
    chosen = ranked[:n_exploit]
    rest = [w for w in ids if w not in chosen]
    if rest and k > n_exploit:
        extra = rng.choice(rest, size=min(k - n_exploit, len(rest)),
                           replace=False).tolist()
        chosen = chosen + list(extra)
    return sorted(chosen)
