"""Aggregation server (paper SSIII-C): model versioning, worker selection,
sync barrier / async merges, and the accuracy-driven policy updates."""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import aggregation, selection
from repro.core.cost_model import WorkerStats


@dataclasses.dataclass
class ServerConfig:
    policy: str = "time_based"      # all|random|sequential|rmin_rmax|time_based
    mode: str = "sync"              # sync | async
    aggregation: str = "fedavg"     # see aggregation.aggregation_weights
    epochs_per_round: int = 2       # r (alg 2) / rmin seed (alg 1)
    random_k: int = 5
    rmin_init: float = 2.0
    rmax_init: float = 4.0
    accuracy_threshold_A: float = 0.015
    async_base_alpha: float = 0.6
    staleness_scheme: str = "polynomial"
    server_opt: str = "avg"         # avg (paper) | avgm | adam | yogi (FedOpt)
    server_lr: float = 1.0


class AggregationServer:
    """Holds the server model + policy state; pure-python control plane."""

    def __init__(self, params, stats: dict[int, WorkerStats],
                 cfg: ServerConfig, *, seed: int = 0, topology=None):
        self.params = params
        self.stats = stats
        self.cfg = cfg
        # Optional hierarchy.FogTopology: sync rounds then aggregate
        # edge->fog->cloud instead of flat (numerically equivalent for
        # matching weights; see core/hierarchy.py).
        self.topology = topology
        self.version = 0
        self.acc_history: list[float] = [0.0]
        self.rng = np.random.default_rng(seed)
        self._rmm = selection.RMinRMaxState(cfg.rmin_init, cfg.rmax_init)
        self._tb = selection.TimeBasedState(
            T=0.0, r=cfg.epochs_per_round, A=cfg.accuracy_threshold_A)
        from repro.core.server_opt import ServerOptimizer
        self._sopt = ServerOptimizer(cfg.server_opt, lr=cfg.server_lr)
        self._sopt_state = self._sopt.init(params)

    # ---- selection ----
    def select(self) -> list[int]:
        c = self.cfg
        if c.policy == "all":
            return selection.select_all(self.stats)
        if c.policy == "sequential":
            # the paper's sequential baseline: the single worker holding data
            with_data = [w for w, s in self.stats.items() if s.n_data > 0]
            return with_data[:1]
        if c.policy == "random":
            return selection.select_random(self.stats, c.random_k, self.rng)
        if c.policy == "rmin_rmax":
            return selection.rmin_rmax_select(self.stats, self._rmm)
        if c.policy == "time_based":
            return selection.time_based_select(self.stats, self._tb)
        if c.policy == "fastest":
            return selection.select_fastest(self.stats, c.random_k,
                                            c.epochs_per_round)
        raise ValueError(f"unknown policy {c.policy}")

    def epochs_for(self, wid: int, round_budget: Optional[float] = None) -> int:
        if self.cfg.policy == "rmin_rmax" and round_budget is not None:
            return selection.epochs_for_worker(self.stats[wid], self._rmm,
                                               round_budget)
        return self.cfg.epochs_per_round

    # ---- aggregation ----
    def sync_aggregate(self, responses: dict[int, object], sim_time: float):
        """responses: wid -> worker params (all based on self.version)."""
        if not responses:
            return
        wids = sorted(responses)
        w = aggregation.aggregation_weights(
            self.cfg.aggregation,
            [max(self.stats[i].n_data, 1) for i in wids],
            staleness=[0.0] * len(wids))
        avg = None
        if self.topology is not None:
            from repro.core import hierarchy
            avg = hierarchy.fog_aggregate_responses(
                responses, dict(zip(wids, w)), self.topology)
        self.params, self._sopt_state = self._sopt.apply(
            self.params, [responses[i] for i in wids], w, self._sopt_state,
            avg=avg)
        for i in wids:
            self.stats[i].last_contribution = sim_time
        self.version += 1

    def async_fold(self, wid: int, worker_params, base_version: int,
                   sim_time: float):
        staleness = self.version - base_version
        alpha = aggregation.staleness_alpha(
            self.cfg.async_base_alpha, staleness,
            scheme=self.cfg.staleness_scheme)
        self.params = aggregation.async_merge(self.params, worker_params,
                                              alpha)
        self.stats[wid].last_contribution = sim_time
        self.version += 1

    # ---- policy feedback (Eq. 1-3) ----
    def record_accuracy(self, acc: float):
        prev = self.acc_history[-1]
        self.acc_history.append(acc)
        if self.cfg.policy == "rmin_rmax":
            self._rmm = selection.rmin_rmax_update(self._rmm, acc)
        elif self.cfg.policy == "time_based":
            st = dataclasses.replace(self._tb, acc_prev=prev)
            self._tb = selection.time_based_update(self.stats, st, acc)

    @property
    def policy_state(self):
        if self.cfg.policy == "rmin_rmax":
            return self._rmm
        if self.cfg.policy == "time_based":
            return self._tb
        return None
