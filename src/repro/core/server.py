"""Aggregation server (paper SSIII-C): model versioning, worker selection,
sync barrier / async merges, and the accuracy-driven policy updates.

Beyond-paper robustness (see core/faults.py for the attack half):

  * SANITIZATION GATE -- every response passes two checks before it can
    touch the server model: a non-finite scan (any NaN/Inf rejects the
    update outright) and a norm-outlier test (delta norm vs the median of
    the batch in sync mode, vs an EWMA of accepted norms in async mode).
    Rejected updates increment per-worker QUARANTINE counters; workers
    whose counter reaches `quarantine_threshold` stop being selected.
  * ROBUST AGGREGATION -- `robust_agg` swaps the weighted average for a
    Byzantine-robust fold (trimmed mean / median / multi-Krum / norm
    clipping, aggregation.ROBUST_METHODS).  With a fog topology the
    robust fold runs per cell and again over the cell aggregates.
  * RETRY/BACKOFF -- async engines consult `retry_policy` after a
    rejection: bounded re-dispatches with exponential backoff.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import aggregation, selection
from repro.core.cost_model import WorkerStats


@dataclasses.dataclass
class ServerConfig:
    policy: str = "time_based"      # all|random|sequential|rmin_rmax|time_based
    mode: str = "sync"              # sync | async
    aggregation: str = "fedavg"     # see aggregation.aggregation_weights
    epochs_per_round: int = 2       # r (alg 2) / rmin seed (alg 1)
    random_k: int = 5
    rmin_init: float = 2.0
    rmax_init: float = 4.0
    accuracy_threshold_A: float = 0.015
    async_base_alpha: float = 0.6
    staleness_scheme: str = "polynomial"
    server_opt: str = "avg"         # avg (paper) | avgm | adam | yogi (FedOpt)
    server_lr: float = 1.0
    # -- robustness (defenses for core/faults.py attacks) --
    robust_agg: str = "none"        # none | aggregation.ROBUST_METHODS
    trim_frac: float = 0.2          # trimmed_mean: trim ceil(frac*P)/side
    krum_f: Optional[int] = None    # krum: assumed Byzantine count
    clip_mult: float = 2.0          # norm_clip: clip at mult x median norm
    norm_outlier_mult: float = 10.0  # sanitize: reject > mult x median/EWMA
    #                                  delta norm (0 disables the norm gate)
    quarantine_threshold: int = 3   # rejections before a worker is benched
    max_retries: int = 2            # async: bounded re-dispatch after reject
    retry_backoff: float = 1.0      # async: base backoff seconds (doubling)


class AggregationServer:
    """Holds the server model + policy state; pure-python control plane."""

    def __init__(self, params, stats: dict[int, WorkerStats],
                 cfg: ServerConfig, *, seed: int = 0, topology=None):
        if cfg.robust_agg not in ("none",) + aggregation.ROBUST_METHODS:
            raise ValueError(f"unknown robust_agg '{cfg.robust_agg}'")
        self.params = params
        self.stats = stats
        self.cfg = cfg
        # Optional hierarchy.FogTopology: sync rounds then aggregate
        # edge->fog->cloud instead of flat (numerically equivalent for
        # matching weights; see core/hierarchy.py).
        self.topology = topology
        self.version = 0
        self.acc_history: list[float] = [0.0]
        self.rng = np.random.default_rng(seed)
        self._rmm = selection.RMinRMaxState(cfg.rmin_init, cfg.rmax_init)
        self._tb = selection.TimeBasedState(
            T=0.0, r=cfg.epochs_per_round, A=cfg.accuracy_threshold_A)
        from repro.core.server_opt import ServerOptimizer
        self._sopt = ServerOptimizer(cfg.server_opt, lr=cfg.server_lr)
        self._sopt_state = self._sopt.init(params)
        # -- sanitization gate state --
        self.quarantine: dict[int, int] = {}    # wid -> rejection count
        self.rejections: list[tuple[int, int, str]] = []  # (version, wid, why)
        self._norm_ewma: Optional[float] = None  # async accepted-norm EWMA
        self._norm_beta = 0.3

    # ---- selection ----
    def _eligible(self) -> dict[int, WorkerStats]:
        thr = self.cfg.quarantine_threshold
        if thr <= 0 or not self.quarantine:
            return self.stats
        return {w: s for w, s in self.stats.items()
                if self.quarantine.get(w, 0) < thr}

    def select(self) -> list[int]:
        c = self.cfg
        stats = self._eligible()
        if c.policy == "all":
            return selection.select_all(stats)
        if c.policy == "sequential":
            # the paper's sequential baseline: the single worker holding data
            with_data = [w for w, s in stats.items() if s.n_data > 0]
            return with_data[:1]
        if c.policy == "random":
            return selection.select_random(stats, c.random_k, self.rng)
        if c.policy == "rmin_rmax":
            return selection.rmin_rmax_select(stats, self._rmm)
        if c.policy == "time_based":
            return selection.time_based_select(stats, self._tb)
        if c.policy == "fastest":
            return selection.select_fastest(stats, c.random_k,
                                            c.epochs_per_round)
        raise ValueError(f"unknown policy {c.policy}")

    def epochs_for(self, wid: int, round_budget: Optional[float] = None) -> int:
        if self.cfg.policy == "rmin_rmax" and round_budget is not None:
            return selection.epochs_for_worker(self.stats[wid], self._rmm,
                                               round_budget)
        return self.cfg.epochs_per_round

    # ---- sanitization gate ----
    def _reject(self, wid: int, why: str):
        self.quarantine[wid] = self.quarantine.get(wid, 0) + 1
        self.rejections.append((self.version, wid, why))

    def note_divergence(self, wid: int):
        """A worker reported a non-finite local step (it skipped and sent
        nothing); feed the quarantine counter so repeat offenders are
        benched like any other rejected sender."""
        self._reject(wid, "local_divergence")

    def sanitize_sync(self, responses: dict[int, object]
                      ) -> dict[int, object]:
        """Drop non-finite responses, then responses whose delta norm from
        the current model exceeds `norm_outlier_mult` x the batch median.
        Quarantine counters record every rejection."""
        finite: dict[int, object] = {}
        for wid, p in responses.items():
            if aggregation.tree_finite(p):
                finite[wid] = p
            else:
                self._reject(wid, "non_finite")
        mult = self.cfg.norm_outlier_mult
        if mult <= 0 or len(finite) < 3:
            return finite
        norms = {w: aggregation.delta_norm(p, self.params)
                 for w, p in finite.items()}
        med = float(np.median(list(norms.values())))
        out: dict[int, object] = {}
        for wid, p in finite.items():
            if med > 0 and norms[wid] > mult * med:
                self._reject(wid, "norm_outlier")
            else:
                out[wid] = p
        return out

    def sanitize_async(self, wid: int, worker_params) -> bool:
        """Gate one async response; True = fold it.  The norm reference is
        an EWMA of previously ACCEPTED delta norms (there is no batch to
        take a median over)."""
        if not aggregation.tree_finite(worker_params):
            self._reject(wid, "non_finite")
            return False
        mult = self.cfg.norm_outlier_mult
        if mult > 0:
            norm = aggregation.delta_norm(worker_params, self.params)
            if self._norm_ewma is not None and self._norm_ewma > 0 \
                    and norm > mult * self._norm_ewma:
                self._reject(wid, "norm_outlier")
                return False
            self._norm_ewma = norm if self._norm_ewma is None else \
                (1 - self._norm_beta) * self._norm_ewma + \
                self._norm_beta * norm
        return True

    def retry_policy(self, wid: int, n_rejects: int
                     ) -> Optional[float]:
        """After a rejected async response: seconds to wait before
        re-dispatching `wid`, or None to give up (bounded retries /
        quarantined worker)."""
        c = self.cfg
        if n_rejects > c.max_retries:
            return None
        if self.quarantine.get(wid, 0) >= c.quarantine_threshold > 0:
            return None
        return c.retry_backoff * (2.0 ** max(n_rejects - 1, 0))

    # ---- aggregation ----
    def _robust_avg(self, responses: dict[int, object], wids: list[int]):
        c = self.cfg
        kw = dict(trim_frac=c.trim_frac, krum_f=c.krum_f,
                  clip_mult=c.clip_mult)
        kw = {k: v for k, v in kw.items() if v is not None}
        if c.robust_agg == "norm_clip":
            kw["base"] = self.params
        if self.topology is not None:
            from repro.core import hierarchy
            return hierarchy.fog_aggregate_responses(
                responses, {w: max(self.stats[w].n_data, 1) for w in wids},
                self.topology, robust=c.robust_agg, robust_kw=kw)
        return aggregation.robust_aggregate(
            [responses[w] for w in wids], c.robust_agg, **kw)

    def sync_aggregate(self, responses: dict[int, object], sim_time: float):
        """responses: wid -> worker params (all based on self.version).
        Every response passes the sanitization gate first; the surviving
        set is folded with the configured (robust or weighted) aggregator.
        """
        responses = self.sanitize_sync(responses)
        if not responses:
            return
        wids = sorted(responses)
        w = aggregation.aggregation_weights(
            self.cfg.aggregation,
            [max(self.stats[i].n_data, 1) for i in wids],
            staleness=[0.0] * len(wids))
        avg = None
        if self.cfg.robust_agg != "none":
            avg = self._robust_avg(responses, wids)
        elif self.topology is not None:
            from repro.core import hierarchy
            avg = hierarchy.fog_aggregate_responses(
                responses, dict(zip(wids, w)), self.topology)
        self.params, self._sopt_state = self._sopt.apply(
            self.params, [responses[i] for i in wids], w, self._sopt_state,
            avg=avg)
        for i in wids:
            self.stats[i].last_contribution = sim_time
        self.version += 1

    def async_fold(self, wid: int, worker_params, base_version: int,
                   sim_time: float) -> bool:
        """Fold one response if it passes the gate; returns True when the
        model advanced (False = rejected, caller may consult
        `retry_policy`)."""
        if not self.sanitize_async(wid, worker_params):
            return False
        staleness = self.version - base_version
        alpha = aggregation.staleness_alpha(
            self.cfg.async_base_alpha, staleness,
            scheme=self.cfg.staleness_scheme)
        self.params = aggregation.async_merge(self.params, worker_params,
                                              alpha)
        self.stats[wid].last_contribution = sim_time
        self.version += 1
        return True

    # ---- policy feedback (Eq. 1-3) ----
    def record_accuracy(self, acc: float):
        prev = self.acc_history[-1]
        self.acc_history.append(acc)
        if self.cfg.policy == "rmin_rmax":
            self._rmm = selection.rmin_rmax_update(self._rmm, acc)
        elif self.cfg.policy == "time_based":
            st = dataclasses.replace(self._tb, acc_prev=prev)
            self._tb = selection.time_based_update(self.stats, st, acc)

    @property
    def policy_state(self):
        if self.cfg.policy == "rmin_rmax":
            return self._rmm
        if self.cfg.policy == "time_based":
            return self._tb
        return None

    # ---- crash-safe state (round-granular checkpointing) ----
    def state_dict(self) -> dict:
        """JSON-serializable control-plane state (params/opt pytrees are
        checkpointed separately by the engines).  Restoring this plus the
        params resumes the server bit-identically (tests/test_resume.py).
        """
        return {
            "version": self.version,
            "acc_history": [float(a) for a in self.acc_history],
            "rng_state": self.rng.bit_generator.state,
            "rmm": dataclasses.asdict(self._rmm),
            "tb": dataclasses.asdict(self._tb),
            "quarantine": {str(k): int(v) for k, v in
                           self.quarantine.items()},
            "norm_ewma": self._norm_ewma,
            "sopt_step": int(self._sopt_state.step),
            "stats": {str(w): {
                "t_one": s.t_one, "t_transmit": s.t_transmit,
                "n_data": s.n_data,
                "last_contribution": s.last_contribution,
                "rounds_participated": s.rounds_participated,
            } for w, s in self.stats.items()},
        }

    def load_state_dict(self, state: dict):
        self.version = int(state["version"])
        self.acc_history = list(state["acc_history"])
        self.rng.bit_generator.state = state["rng_state"]
        self._rmm = selection.RMinRMaxState(**state["rmm"])
        self._tb = selection.TimeBasedState(**state["tb"])
        self.quarantine = {int(k): int(v) for k, v in
                           state.get("quarantine", {}).items()}
        self._norm_ewma = state.get("norm_ewma")
        self._sopt_state = dataclasses.replace(
            self._sopt_state, step=int(state.get("sopt_step", 0)))
        for w, d in state["stats"].items():
            s = self.stats.get(int(w))
            if s is None:
                continue
            s.t_one = float(d["t_one"])
            s.t_transmit = float(d["t_transmit"])
            s.n_data = int(d["n_data"])
            s.last_contribution = float(d["last_contribution"])
            s.rounds_participated = int(d["rounds_participated"])
