"""Server-side optimizers for federated aggregation (beyond-paper).

The paper folds worker weights by plain (weighted) averaging.  FedOpt
(Reddi et al. 2021) instead treats the average worker DELTA as a
pseudo-gradient and applies a server optimizer -- FedAvgM / FedAdam /
FedYogi -- which materially speeds convergence under heterogeneity.  These
compose with every FLight selection policy and with both execution tiers
(the Tier-B form is one extra elementwise pass after `mix_islands`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import aggregation


@dataclasses.dataclass
class ServerOptState:
    momentum: object = None       # pytree like params
    variance: object = None       # pytree like params (adam/yogi)
    step: int = 0


@dataclasses.dataclass
class ServerOptimizer:
    """method: 'avg' (paper) | 'avgm' | 'adam' | 'yogi'."""
    method: str = "avg"
    lr: float = 1.0
    beta1: float = 0.9
    beta2: float = 0.99
    eps: float = 1e-3

    def init(self, params) -> ServerOptState:
        z = lambda: jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if self.method == "avg":
            return ServerOptState()
        if self.method == "avgm":
            return ServerOptState(momentum=z())
        return ServerOptState(momentum=z(), variance=z())

    def apply(self, server_params, worker_params_list, weights,
              state: ServerOptState, *, avg=None):
        """-> (new_server_params, new_state).  worker list is the selected
        responses; weights as in aggregation.aggregation_weights.  `avg`
        short-circuits the flat weighted average when the caller already
        aggregated (e.g. through the edge->fog->cloud tier)."""
        if avg is None:
            avg = aggregation.weighted_average(worker_params_list, weights)
        if self.method == "avg":
            return avg, state

        delta = jax.tree.map(
            lambda a, s: a.astype(jnp.float32) - s.astype(jnp.float32),
            avg, server_params)
        m = jax.tree.map(
            lambda mo, d: self.beta1 * mo + (1 - self.beta1) * d,
            state.momentum, delta)
        if self.method == "avgm":
            new = jax.tree.map(
                lambda s, mo: (s.astype(jnp.float32) + self.lr * mo)
                .astype(s.dtype), server_params, m)
            return new, ServerOptState(momentum=m, step=state.step + 1)

        if self.method == "adam":
            v = jax.tree.map(
                lambda vo, d: self.beta2 * vo + (1 - self.beta2) * d * d,
                state.variance, delta)
        elif self.method == "yogi":
            v = jax.tree.map(
                lambda vo, d: vo - (1 - self.beta2) * d * d
                * jnp.sign(vo - d * d),
                state.variance, delta)
        else:
            raise ValueError(self.method)
        new = jax.tree.map(
            lambda s, mo, vo: (s.astype(jnp.float32)
                               + self.lr * mo / (jnp.sqrt(vo) + self.eps))
            .astype(s.dtype), server_params, m, v)
        return new, ServerOptState(momentum=m, variance=v,
                                   step=state.step + 1)
