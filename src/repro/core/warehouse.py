"""Data warehouse (paper SSIII-B.1): pointer-addressed storage for model
weights with one-time fetch credentials.

The paper separates the CONTROL channel (small messages) from the BULK
channel (FTP side-channel for weights, fetched with one-time credentials).
Here: storage backends are RAM or disk (.npz); the credential dance is kept
because it is the paper's access-control mechanism and doubles as our
checkpoint-integrity layer (a credential is valid once, so a crashed fetch
can never double-apply a stale model).
"""
from __future__ import annotations

import dataclasses
import io
import os
import secrets
import tempfile
from pathlib import Path
from typing import Optional

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class Pointer:
    """Uniquely identifies a model on a (possibly remote) warehouse."""
    address: str          # warehouse network address ("local" in-process)
    uid: str              # unique ID within that warehouse


class CredentialError(KeyError):
    pass


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class DataWarehouse:
    """getter/setter store for pytrees keyed by unique IDs (SSIII-B.1)."""

    def __init__(self, root: Optional[str] = None, address: str = "local"):
        self.address = address
        self.root = Path(root) if root else None
        if self.root:
            self.root.mkdir(parents=True, exist_ok=True)
        self._mem: dict[str, object] = {}
        self._disk: dict[str, tuple[Path, object]] = {}  # uid -> (path, treedef)
        self._credentials: dict[str, str] = {}           # token -> uid

    # ---- setters ----
    def put(self, tree, *, storage: str = "memory", uid: Optional[str] = None
            ) -> Pointer:
        uid = uid or secrets.token_hex(8)
        if storage == "memory" or self.root is None:
            self._mem[uid] = jax.tree.map(lambda x: x, tree)
        elif storage == "disk":
            leaves, treedef = _flatten(tree)
            path = self.root / f"{uid}.npz"
            tmp = path.with_suffix(".tmp.npz")
            np.savez(tmp, **{f"a{i}": np.asarray(l) for i, l in
                             enumerate(leaves)})
            os.replace(tmp, path)  # atomic publish
            self._disk[uid] = (path, treedef)
        else:
            raise ValueError(f"unknown storage '{storage}'")
        return Pointer(self.address, uid)

    # ---- getters ----
    def get(self, uid: str):
        if uid in self._mem:
            return self._mem[uid]
        if uid in self._disk:
            path, treedef = self._disk[uid]
            with np.load(path) as z:
                leaves = [z[f"a{i}"] for i in range(len(z.files))]
            return jax.tree.unflatten(treedef, leaves)
        raise KeyError(uid)

    def exists(self, uid: str) -> bool:
        return uid in self._mem or uid in self._disk

    def delete(self, uid: str):
        self._mem.pop(uid, None)
        entry = self._disk.pop(uid, None)
        if entry:
            entry[0].unlink(missing_ok=True)

    # ---- one-time credential dance (the FTP side-channel analogue) ----
    def issue_credential(self, uid: str) -> str:
        if not self.exists(uid):
            raise KeyError(uid)
        token = secrets.token_hex(16)
        self._credentials[token] = uid
        return token

    def fetch(self, token: str):
        uid = self._credentials.pop(token, None)
        if uid is None:
            raise CredentialError("invalid or already-used credential")
        return self.get(uid)
