from repro.data.partition import (paper_table3, paper_table4,
                                  partition_by_batches, dirichlet_partition)
from repro.data.synthetic import make_classification_set, make_token_stream
