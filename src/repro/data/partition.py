"""Federated data partitioner -- the paper's Tables III/IV verbatim, plus
general batch-count and Dirichlet non-IID partitioners.

The paper allocates BATCHES of data per worker; configs 1/4 put everything
on W1 (the sequential baseline), 2/5 are even, 3/6 uneven.  Data is split
WITHOUT overlap (paper: 'all workers have ... distinct training data').
"""
from __future__ import annotations

import numpy as np

# --- Table III: 10 workers.  worker index -> batches, per config ----------
# columns: W1, W2/W3, W4, W5/W6, W7, W8/W9/W10
_T3 = {
    1: ("synmnist", [10, 0, 0, 0, 0, 0, 0, 0, 0, 0]),
    2: ("synmnist", [1] * 10),
    3: ("synmnist", [1, 0, 0, 3, 0, 0, 0, 2, 2, 2]),
    4: ("syncifar", [100, 0, 0, 0, 0, 0, 0, 0, 0, 0]),
    5: ("syncifar", [10] * 10),
    6: ("syncifar", [10, 0, 0, 30, 0, 0, 0, 20, 20, 20]),
}

# --- Table IV: 30 workers --------------------------------------------------
# columns: W1, W2-W10, W11, W12-W20, W21, W22-W30
def _t4_row(w1, w2_10, w11, w12_20, w21, w22_30):
    return [w1] + [w2_10] * 9 + [w11] + [w12_20] * 9 + [w21] + [w22_30] * 9

_T4 = {
    1: ("synmnist", _t4_row(30, 0, 0, 0, 0, 0)),
    2: ("synmnist", [1] * 30),
    3: ("synmnist", _t4_row(4, 0, 8, 0, 0, 2)),
    4: ("syncifar", _t4_row(300, 0, 0, 0, 0, 0)),
    5: ("syncifar", [10] * 30),
    6: ("syncifar", _t4_row(40, 0, 80, 0, 0, 20)),
}


def paper_table3(config: int):
    """-> (dataset_kind, batches_per_worker list, n_workers=10)."""
    kind, rows = _T3[config]
    return kind, list(rows)


def paper_table4(config: int):
    kind, rows = _T4[config]
    return kind, list(rows)


def partition_by_batches(images, labels, batches_per_worker, *,
                         batch_size: int = 64, seed: int = 0):
    """Split (images, labels) into disjoint worker shards of
    `batches_per_worker[i] * batch_size` samples each."""
    n_needed = sum(batches_per_worker) * batch_size
    assert n_needed <= len(images), (n_needed, len(images))
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(images))[:n_needed]
    shards, off = [], 0
    for nb in batches_per_worker:
        take = nb * batch_size
        idx = order[off: off + take]
        shards.append((images[idx], labels[idx]))
        off += take
    return shards


def dirichlet_partition(images, labels, n_workers: int, *, alpha: float = 0.5,
                        seed: int = 0):
    """Label-skewed non-IID split (beyond-paper; standard FL benchmark)."""
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    idx_by_class = [np.where(labels == c)[0] for c in range(n_classes)]
    worker_idx = [[] for _ in range(n_workers)]
    for idx in idx_by_class:
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * n_workers)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for w, part in enumerate(np.split(idx, cuts)):
            worker_idx[w].extend(part.tolist())
    return [(images[np.array(ix, int)], labels[np.array(ix, int)])
            if ix else (images[:0], labels[:0]) for ix in worker_idx]
