"""Deterministic synthetic datasets (offline stand-ins; see DESIGN.md SS9).

synMNIST / synCIFAR: 10-class Gaussian-prototype images.  Each class has a
fixed random prototype; samples are prototype + noise (+ per-sample random
shift), so the task is learnable but not trivial -- small CNN/MLP reach
>90% (synMNIST) / ~50-70% (synCIFAR, higher noise), mirroring the paper's
MNIST/CIFAR accuracy regimes.

Token streams: Zipf-distributed token sequences for LM examples.
"""
from __future__ import annotations

import zlib

import numpy as np


def make_classification_set(kind: str, n: int, *, seed: int = 0):
    """kind: 'synmnist' (28x28x1) | 'syncifar' (32x32x3).
    Returns (images float32 [0,1], labels int32).

    Class prototypes are a FIXED function of `kind` (crc32-seeded, stable
    across processes): every split of the same kind shares one class
    structure, while `seed` only drives sampling/noise -- so a train split
    generalises to a test split."""
    if kind == "synmnist":
        hw, c, noise = 28, 1, 0.35
    elif kind == "syncifar":
        hw, c, noise = 32, 3, 2.0  # much noisier: ~50-60% achievable, the
        # paper's CIFAR regime (its Fig.16 cites ~50% theoretical accuracy)
    else:
        raise ValueError(kind)
    proto_rng = np.random.default_rng(zlib.crc32(kind.encode()))
    protos = proto_rng.normal(0.5, 0.35, size=(10, hw, hw, c))
    rng = np.random.default_rng(zlib.crc32(f"{kind}-{seed}".encode()))
    labels = rng.integers(0, 10, size=n).astype(np.int32)
    imgs = protos[labels]
    # per-sample jitter: small spatial roll + gaussian noise
    rolls = rng.integers(-2, 3, size=(n, 2))
    out = np.empty((n, hw, hw, c), np.float32)
    for shift in np.unique(rolls, axis=0):
        m = (rolls == shift).all(axis=1)
        out[m] = np.roll(imgs[m], tuple(shift), axis=(1, 2))
    out += rng.normal(0.0, noise, size=out.shape)
    return np.clip(out, 0.0, 1.0).astype(np.float32), labels


def make_token_stream(vocab: int, n_tokens: int, *, seed: int = 0,
                      zipf_a: float = 1.2) -> np.ndarray:
    """Zipf token stream with a weak bigram structure (next ~ prev + noise),
    enough signal for an LM to show decreasing loss."""
    rng = np.random.default_rng(seed)
    base = rng.zipf(zipf_a, size=n_tokens).astype(np.int64)
    toks = (base - 1) % vocab
    # bigram coupling: with p=0.3 the next token repeats (prev+1) % vocab
    rep = rng.random(n_tokens) < 0.3
    toks[1:][rep[1:]] = (toks[:-1][rep[1:]] + 1) % vocab
    return toks.astype(np.int32)


def batch_token_stream(stream: np.ndarray, batch: int, seq_len: int,
                       step: int):
    """Slice deterministic (tokens, labels) LM batches from a stream."""
    need = batch * (seq_len + 1)
    off = (step * need) % max(len(stream) - need - 1, 1)
    window = stream[off: off + need]
    x = window[: batch * seq_len].reshape(batch, seq_len)
    y = window[1: batch * seq_len + 1].reshape(batch, seq_len)
    return x, y
