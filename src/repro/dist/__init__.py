"""Distribution layer: logical-axis sharding rules + static HLO cost models.

Submodules (import them directly; nothing heavy happens at package import):
  sharding     -- logical-axis -> PartitionSpec resolution, constrain(),
                  rule sets (DEFAULT / ISLAND / SERVE / HYBRID_SERVE) and
                  the serve_layout_rules() factory used by every model
  policy       -- memory-aware serve-layout policy: scores the candidate
                  layouts (stationary / hybrid / fsdp) by peak per-device
                  HBM + predicted step time and picks one per cell
  hlo_cost     -- trip-count-aware HLO-text cost model (XLA's own
                  cost_analysis counts scan bodies once; ours multiplies)
  hlo_analysis -- collective-traffic accounting, XLA cost/memory analysis
                  extraction, and the Roofline estimator + HW constants
"""
