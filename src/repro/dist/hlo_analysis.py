"""Collective-traffic accounting + XLA analysis extraction + roofline.

Hardware model (one v5e-class chip; see DESIGN notes in
benchmarks/roofline.py):
  PEAK_FLOPS_BF16  197 TFLOP/s
  HBM_BW           819 GB/s
  ICI_BW           50 GB/s per link

`collective_bytes(text)` is a ONE-PASS text scan (no trip-count
multiplication -- use dist.hlo_cost.analyze for that); it exists so the
dry-run can record the per-program collective mix cheaply and so tests can
pin the opcode accounting (-start counted once, -done never).
"""
from __future__ import annotations

import dataclasses
import re

from repro.dist.hlo_cost import (is_collective, leaf_bytes,
                                 normalize_collective, parse_shape)

PEAK_FLOPS_BF16 = 197e12   # flop/s
HBM_BW = 819e9             # byte/s
ICI_BW = 50e9              # byte/s per link


# ---------------------------------------------------------------------------
# Collective traffic (single-pass, text level)
# ---------------------------------------------------------------------------

_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%[\w.\-]+\s*=\s*(\([^=]*?\)|\w+\[[^\]]*\](?:\{[^}]*\})?)"
    r"\s+([\w\-]+)\(")


def collective_bytes(text: str) -> dict:
    """Sum output bytes of every collective instruction in `text`.

    Returns {"by_op": {base_opcode: bytes}, "count": n, "total_bytes": b}.
    Async pairs count once: `-start` carries the shape, `-done` is skipped.
    """
    by_op: dict[str, float] = {}
    count = 0
    for line in text.splitlines():
        m = _INSTR.match(line)
        if not m:
            continue
        type_str, opcode = m.group(1), m.group(2)
        if not is_collective(opcode):
            continue
        base = normalize_collective(opcode)
        nbytes = leaf_bytes(parse_shape(type_str))
        by_op[base] = by_op.get(base, 0.0) + nbytes
        count += 1
    return {"by_op": by_op, "count": count,
            "total_bytes": sum(by_op.values())}


# ---------------------------------------------------------------------------
# XLA compiled-module analyses (version tolerant)
# ---------------------------------------------------------------------------

def cost_analysis_terms(compiled) -> tuple[float, float]:
    """(flops, bytes_accessed) from compiled.cost_analysis(); 0.0 when the
    backend does not report a term.  NOTE: XLA counts loop bodies ONCE --
    use dist.hlo_cost for trip-count-aware totals."""
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return 0.0, 0.0
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    if not isinstance(ca, dict):
        return 0.0, 0.0
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", ca.get("bytes_accessed", 0.0))))


_MEM_FIELDS = ("argument_size_in_bytes", "output_size_in_bytes",
               "temp_size_in_bytes", "alias_size_in_bytes",
               "generated_code_size_in_bytes")


def memory_analysis_dict(compiled) -> dict:
    """compiled.memory_analysis() flattened to a plain dict (or {})."""
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for f in _MEM_FIELDS:
        v = getattr(ma, f, None)
        if v is not None:
            out[f] = int(v)
    return out


# ---------------------------------------------------------------------------
# Roofline
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Roofline:
    """Three-term per-device roofline: compute vs HBM vs interconnect."""
    flops: float
    hbm_bytes: float
    collective_bytes: float = 0.0
    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def t_compute_s(self) -> float:
        return self.flops / self.peak_flops

    @property
    def t_memory_s(self) -> float:
        return self.hbm_bytes / self.hbm_bw

    @property
    def t_collective_s(self) -> float:
        return self.collective_bytes / self.ici_bw

    @property
    def bound_s(self) -> float:
        return max(self.t_compute_s, self.t_memory_s, self.t_collective_s)

    @property
    def dominant(self) -> str:
        terms = (("compute", self.t_compute_s), ("memory", self.t_memory_s),
                 ("collective", self.t_collective_s))
        return max(terms, key=lambda kv: kv[1])[0]

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.hbm_bytes, 1e-9)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "t_compute_s": self.t_compute_s,
            "t_memory_s": self.t_memory_s,
            "t_collective_s": self.t_collective_s,
            "bound_s": self.bound_s,
            "dominant": self.dominant,
            "arithmetic_intensity": self.arithmetic_intensity,
        }
