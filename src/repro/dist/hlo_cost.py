"""Trip-count-aware cost model over post-compile HLO text.

Why not `compiled.cost_analysis()`?  XLA counts every computation ONCE, so a
scanned program reports the flops of a single trip: an 8-step scan and its
unrolled twin differ by 8x (see tests/test_hlo_cost.py::
test_xla_cost_analysis_undercounts_scans).  This module re-derives
flops/bytes from the HLO text and MULTIPLIES nested while-loop trip counts,
so scanned and unrolled programs report matching totals.

Structure:
  ModuleCost(text)   -- parses computations/ops/constants out of the text
  mc.op_cost(c, op)  -- static cost of one op in its computation context
  analyze(text)      -- walk the call graph from ENTRY with trip-count
                        multipliers; returns {flops, hbm_bytes,
                        collective_bytes, collective_by_op,
                        transcendentals, diagnostics}

Memory model (the fusion-boundary model): ops in fused computations are
register-resident (flops only); fusion/while boundaries charge HBM.  Two
window rules keep loop-carried programs honest:

  * dynamic-(update-)slice WRITES charge the update window, not the
    aliased operand -- scan ys writes must not be billed the full stacked
    array every trip (tests/test_hlo_cost.py::
    test_dus_counts_window_not_operand);
  * fusion parameter READS consumed only through dynamic-slice / slice /
    gather windows (possibly via bitcast/reshape/transpose views) charge
    the window bytes, capped at the buffer size.  A scan body that slices
    layer `l` out of stacked (L, ...) weights therefore streams the stack
    ONCE across L trips instead of L times, and XLA's per-element
    select-and-scatter expansion (CNN maxpool backward: a 50k-trip while
    loop of scalar updates) bills scalars, not the whole feature map.
    Before this calibration the CNN-on-256-device cell reported ~3600x
    XLA's `bytes accessed`; after it the two agree within 2x
    (tests/test_policy.py::test_cnn_hbm_calibrated_vs_xla).
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

# ---------------------------------------------------------------------------
# Shape / type parsing
# ---------------------------------------------------------------------------

_SPECIAL_BYTES = {"pred": 1, "token": 0, "opaque": 0}


def _dtype_bytes(dtype: str) -> float:
    if dtype in _SPECIAL_BYTES:
        return _SPECIAL_BYTES[dtype]
    m = re.search(r"(\d+)", dtype)
    return int(m.group(1)) / 8 if m else 4


def _parse_dims(inner: str) -> list[int]:
    dims = []
    for tok in inner.split(","):
        tok = tok.strip().lstrip("<=")
        if tok:
            dims.append(int(tok))
    return dims


def parse_shape(s: str) -> list[tuple[str, list[int]]]:
    """HLO type string -> flat list of (dtype, dims) array leaves."""
    s = s.strip()
    if s.startswith("("):
        depth = 0
        for i, ch in enumerate(s):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return _split_tuple(s[1:i])
        return []
    m = re.match(r"(\w+)\[([^\]]*)\]", s)
    if not m:
        return []
    return [(m.group(1), _parse_dims(m.group(2)))]


def _split_tuple(inner: str) -> list[tuple[str, list[int]]]:
    # split on top-level commas only: dims "[128,128]" and layouts "{1,0}"
    # contain commas too, so track every bracket kind, not just parens
    leaves, depth, start = [], 0, 0
    for i, ch in enumerate(inner):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == "," and depth == 0:
            leaves.extend(parse_shape(inner[start:i]))
            start = i + 1
    leaves.extend(parse_shape(inner[start:]))
    return leaves


def leaf_bytes(leaves) -> float:
    return sum(_dtype_bytes(dt) * math.prod(dims) for dt, dims in leaves)


def leaf_elems(leaves) -> int:
    return sum(math.prod(dims) for dims in (d for _, d in leaves))


# ---------------------------------------------------------------------------
# Instruction / computation parsing
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    out_leaves: list          # [(dtype, dims), ...]
    operands: list            # operand instruction names (same computation)
    attrs: str                # raw attribute tail (calls=, backend_config=..)
    is_root: bool = False
    const_val: int | float | None = None   # scalar constants only
    param_idx: int | None = None           # parameter(N)

    @property
    def out_bytes(self) -> float:
        return leaf_bytes(self.out_leaves)

    @property
    def out_elems(self) -> int:
        return leaf_elems(self.out_leaves)

    def called(self) -> list[str]:
        """Computation names referenced by this op (calls/body/...)."""
        names = re.findall(
            r"(?:calls|to_apply|body|condition|branch_computations)="
            r"(\{[^}]*\}|%[\w.\-]+)", self.attrs)
        out = []
        for n in names:
            out.extend(re.findall(r"%([\w.\-]+)", n))
        return out

    def attr_called(self, key: str) -> str | None:
        m = re.search(rf"{key}=%([\w.\-]+)", self.attrs)
        return m.group(1) if m else None


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool = False
    ops: list = dataclasses.field(default_factory=list)
    kind: str = "control"     # control | fused | applied

    def __post_init__(self):
        self.by_name = {}

    def add(self, op: Op):
        self.ops.append(op)
        self.by_name[op.name] = op

    @property
    def root(self) -> Op | None:
        for op in self.ops:
            if op.is_root:
                return op
        return self.ops[-1] if self.ops else None


_COMP_RE = re.compile(
    r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_INSTR_RE = re.compile(r"^\s*(ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")


def _balanced(s: str, start: int) -> int:
    """Index just past the ')' matching the '(' at s[start]."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


def _parse_instr(text: str, is_root: bool, name: str) -> Op | None:
    # text: "<type> <opcode>(<operands>)<attrs>"
    text = text.strip()
    if text.startswith("("):
        end = _balanced(text, 0)
        type_str, rest = text[:end], text[end:]
    else:
        sp = text.find(" ")
        if sp < 0:
            return None
        type_str, rest = text[:sp], text[sp:]
    rest = rest.strip()
    m = re.match(r"([\w\-]+)", rest)
    if not m:
        return None
    opcode = m.group(1)
    paren = rest.find("(", m.end())
    if paren < 0:
        span, attrs = "", rest[m.end():]
    else:
        end = _balanced(rest, paren)
        span, attrs = rest[paren + 1:end - 1], rest[end:]
    operands = re.findall(r"%([\w.\-]+)", span)
    op = Op(name=name, opcode=opcode, out_leaves=parse_shape(type_str),
            operands=operands, attrs=attrs, is_root=is_root)
    if opcode == "constant":
        lit = span.strip().rstrip("fF")
        try:
            op.const_val = int(lit)
        except ValueError:
            try:
                op.const_val = float(lit)
            except ValueError:
                op.const_val = None
    elif opcode == "parameter":
        try:
            op.param_idx = int(span.strip())
        except ValueError:
            pass
    return op


# ---------------------------------------------------------------------------
# Cost tables
# ---------------------------------------------------------------------------

TRANSCENDENTAL = {
    "tanh", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "logistic", "rsqrt", "sqrt", "cbrt", "power", "sine", "cosine", "tan",
    "atan2", "erf", "erf-inv",
}
ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "compare", "select", "and", "or", "xor", "not", "negate", "abs",
    "sign", "floor", "ceil", "round-nearest-afz", "round-nearest-even",
    "clamp", "remainder", "shift-left", "shift-right-arithmetic",
    "shift-right-logical", "is-finite", "clz", "popcnt", "stochastic-convert",
} | TRANSCENDENTAL
# Pure data movement / metadata: no flops, and no HBM charge beyond what
# their consumers already pay (GTE/tuple/bitcast are free; parameters and
# constants live wherever their consumers read them).
ZERO_COST = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id", "iota",
    "reshape", "opt-barrier", "custom-call", "get-dimension-size", "domain",
    "rng-get-and-update-state",
}
COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
}


def normalize_collective(opcode: str) -> str:
    return opcode[:-6] if opcode.endswith("-start") else opcode


def is_collective(opcode: str) -> bool:
    if opcode.endswith("-done"):
        return False
    return normalize_collective(opcode) in COLLECTIVES


@dataclasses.dataclass
class OpCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    transcendentals: float = 0.0
    collective_bytes: float = 0.0


# ---------------------------------------------------------------------------
# ModuleCost
# ---------------------------------------------------------------------------

class ModuleCost:
    def __init__(self, text: str):
        self.comps: dict[str, Computation] = {}
        self.entry: str | None = None
        self._parse(text)
        self._classify()

    # -- parsing ----------------------------------------------------------
    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if cur is None:
                m = _COMP_RE.match(line)
                if m and " = " not in line:
                    cur = Computation(m.group(2), is_entry=bool(m.group(1)))
                    self.comps[cur.name] = cur
                    if cur.is_entry:
                        self.entry = cur.name
                continue
            if line.strip() == "}":
                cur = None
                continue
            m = _INSTR_RE.match(line)
            if m:
                op = _parse_instr(m.group(3), bool(m.group(1)), m.group(2))
                if op is not None:
                    cur.add(op)
        if self.entry is None and self.comps:
            self.entry = next(iter(self.comps))

    def _classify(self):
        for comp in self.comps.values():
            for op in comp.ops:
                fused = op.attr_called("calls")
                if fused and fused in self.comps:
                    self.comps[fused].kind = "fused"
                applied = op.attr_called("to_apply")
                if applied and applied in self.comps:
                    self.comps[applied].kind = "applied"

    # -- per-op flops -----------------------------------------------------
    def _dot_flops(self, comp: Computation, op: Op) -> float:
        contracted = 1
        m = re.search(r"lhs_contracting_dims=\{([^}]*)\}", op.attrs)
        lhs = comp.by_name.get(op.operands[0]) if op.operands else None
        if m and lhs is not None and lhs.out_leaves:
            dims = lhs.out_leaves[0][1]
            for i in _parse_dims(m.group(1)):
                if i < len(dims):
                    contracted *= dims[i]
        return 2.0 * op.out_elems * contracted

    def _conv_flops(self, comp: Computation, op: Op) -> float:
        kernel = (comp.by_name.get(op.operands[1])
                  if len(op.operands) > 1 else None)
        if kernel is None or not kernel.out_leaves:
            return 2.0 * op.out_elems
        kdims = kernel.out_leaves[0][1]
        kelems = math.prod(kdims)
        out_ch = 1
        m = re.search(r"dim_labels=\w+_(\w+)->", op.attrs)
        if m and "o" in m.group(1):
            pos = m.group(1).index("o")
            if pos < len(kdims):
                out_ch = kdims[pos]
        else:
            out_ch = max(kdims) if kdims else 1
        return 2.0 * op.out_elems * kelems / max(out_ch, 1)

    def op_flops(self, comp: Computation, op: Op) -> tuple[float, float]:
        """(flops, transcendentals) for one op."""
        oc = op.opcode
        if oc == "dot":
            return self._dot_flops(comp, op), 0.0
        if oc == "convolution":
            return self._conv_flops(comp, op), 0.0
        if oc in TRANSCENDENTAL:
            n = float(op.out_elems)
            return n, n
        if oc in ELEMENTWISE:
            return float(op.out_elems), 0.0
        if oc in ("reduce", "reduce-window", "select-and-scatter"):
            src = comp.by_name.get(op.operands[0]) if op.operands else None
            return float(src.out_elems if src else op.out_elems), 0.0
        if oc == "scatter":
            upd = (comp.by_name.get(op.operands[2])
                   if len(op.operands) > 2 else None)
            return float(upd.out_elems if upd else op.out_elems), 0.0
        return 0.0, 0.0

    # -- per-op memory ----------------------------------------------------
    def _operand_bytes(self, comp: Computation, op: Op) -> list[float]:
        out = []
        for name in op.operands:
            src = comp.by_name.get(name)
            out.append(src.out_bytes if src is not None else 0.0)
        return out

    def _trace_to_param(self, comp: Computation, name: str) -> int | None:
        seen = set()
        while name in comp.by_name and name not in seen:
            seen.add(name)
            op = comp.by_name[name]
            if op.opcode == "parameter":
                return op.param_idx
            if op.opcode in ("bitcast", "copy", "reshape",
                             "get-tuple-element", "transpose"):
                if not op.operands:
                    return None
                name = op.operands[0]
                continue
            return None
        return None

    _VIEW_OPS = frozenset({"bitcast", "reshape", "copy", "transpose",
                           "get-tuple-element"})
    _WINDOW_READS = frozenset({"dynamic-slice", "slice", "gather"})

    def _param_read_bytes(self, fused: Computation, pidx: int, full: float,
                          root_dus: set) -> float:
        """HBM read charge for fusion parameter `pidx`.

        A parameter consumed ONLY through slice windows (directly or via
        pure view ops) charges the window bytes, capped at the buffer size:
        a scan body slicing layer l of stacked weights streams the stack
        once across all trips, not once per trip.  Any other use reads the
        whole buffer.  Uses by a root dynamic-update-slice in `root_dus`
        are the in-place alias -- already charged as the window write.
        """
        aliases = {o.name for o in fused.ops
                   if o.opcode == "parameter" and o.param_idx == pidx}
        if not aliases:
            return full
        changed = True
        while changed:
            changed = False
            for o in fused.ops:
                if o.name not in aliases and o.opcode in self._VIEW_OPS \
                        and o.operands and o.operands[0] in aliases:
                    aliases.add(o.name)
                    changed = True
        windowed = 0.0
        for u in fused.ops:
            if u.name in aliases:
                continue
            for j, nm in enumerate(u.operands):
                if nm not in aliases:
                    continue
                if u.name in root_dus and j == 0:
                    continue      # in-place alias: the window write pays
                if u.opcode in self._WINDOW_READS and j == 0:
                    windowed += u.out_bytes
                else:
                    return full
        return min(windowed, full)

    def _fusion_hbm(self, comp: Computation, op: Op) -> float:
        fused_name = op.attr_called("calls")
        fused = self.comps.get(fused_name)
        operand_bytes = self._operand_bytes(comp, op)
        out_bytes = op.out_bytes
        if fused is None or fused.root is None:
            return sum(operand_bytes) + out_bytes
        root = fused.root
        dus_roots = []
        if root.opcode == "dynamic-update-slice":
            dus_roots = [root]
        elif root.opcode == "tuple":
            dus_roots = [fused.by_name[n] for n in root.operands
                         if n in fused.by_name
                         and fused.by_name[n].opcode == "dynamic-update-slice"]
        root_dus = set()
        for dus in dus_roots:
            if len(dus.operands) < 2:
                continue
            upd = fused.by_name.get(dus.operands[1])
            upd_bytes = upd.out_bytes if upd else 0.0
            # write the window, not the whole aliased buffer
            out_bytes = max(out_bytes - dus.out_bytes, 0.0) + upd_bytes
            if self._trace_to_param(fused, dus.operands[0]) is not None:
                root_dus.add(dus.name)
        reads = sum(self._param_read_bytes(fused, i, b, root_dus)
                    for i, b in enumerate(operand_bytes))
        return reads + out_bytes

    def op_hbm(self, comp: Computation, op: Op) -> float:
        if comp.kind != "control":
            return 0.0        # fused / applied: register-resident
        oc = op.opcode
        if oc in ZERO_COST or oc in ("while", "call", "conditional"):
            return 0.0        # control flow is charged inside callees
        if oc == "fusion":
            return self._fusion_hbm(comp, op)
        if oc in ("dynamic-slice", "slice"):
            return 2.0 * op.out_bytes
        if oc == "dynamic-update-slice":
            upd = (comp.by_name.get(op.operands[1])
                   if len(op.operands) > 1 else None)
            return 2.0 * (upd.out_bytes if upd else op.out_bytes)
        if oc == "gather":
            idx = (comp.by_name.get(op.operands[1])
                   if len(op.operands) > 1 else None)
            return 2.0 * op.out_bytes + (idx.out_bytes if idx else 0.0)
        if oc in ("broadcast",):
            return op.out_bytes + sum(self._operand_bytes(comp, op))
        return sum(self._operand_bytes(comp, op)) + op.out_bytes

    # -- combined ---------------------------------------------------------
    def op_cost(self, comp: Computation, op: Op) -> OpCost:
        flops, trans = self.op_flops(comp, op)
        hbm = self.op_hbm(comp, op)
        coll = 0.0
        if is_collective(op.opcode):
            coll = max(op.out_bytes,
                       sum(self._operand_bytes(comp, op)))
        return OpCost(flops=flops, hbm_bytes=hbm, transcendentals=trans,
                      collective_bytes=coll)

    # -- trip counts ------------------------------------------------------
    def trip_count(self, op: Op) -> int | None:
        m = re.search(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"?(\d+)',
                      op.attrs)
        if m:
            return int(m.group(1))
        cond_name = op.attr_called("condition")
        cond = self.comps.get(cond_name)
        if cond is None or cond.root is None:
            return None
        root = cond.root
        if root.opcode != "compare":
            return None
        dm = re.search(r"direction=(\w+)", root.attrs)
        direction = dm.group(1) if dm else "LT"
        for name in root.operands:
            src = cond.by_name.get(name)
            if src is not None and src.opcode == "constant" \
                    and isinstance(src.const_val, int):
                # jax scans count 0..N-1 step 1
                return src.const_val + (1 if direction == "LE" else 0)
        return None

    # -- whole-module walk ------------------------------------------------
    def analyze(self) -> dict:
        totals = OpCost()
        by_op: dict[str, float] = defaultdict(float)
        diags: list[str] = []

        def walk(comp_name: str, mult: float, stack: tuple):
            comp = self.comps.get(comp_name)
            if comp is None:
                diags.append(f"missing computation %{comp_name}")
                return
            if comp_name in stack:
                diags.append(f"recursive call into %{comp_name}; skipped")
                return
            stack = stack + (comp_name,)
            for op in comp.ops:
                c = self.op_cost(comp, op)
                totals.flops += c.flops * mult
                totals.hbm_bytes += c.hbm_bytes * mult
                totals.transcendentals += c.transcendentals * mult
                if c.collective_bytes:
                    totals.collective_bytes += c.collective_bytes * mult
                    by_op[normalize_collective(op.opcode)] += \
                        c.collective_bytes * mult
                oc = op.opcode
                if oc == "while":
                    trips = self.trip_count(op)
                    if trips is None:
                        diags.append(
                            f"unknown trip count for %{op.name}; assuming 1")
                        trips = 1
                    body = op.attr_called("body")
                    cond = op.attr_called("condition")
                    if body:
                        walk(body, mult * trips, stack)
                    if cond:
                        walk(cond, mult * (trips + 1), stack)
                elif oc == "fusion":
                    callee = op.attr_called("calls")
                    if callee:
                        walk(callee, mult, stack)
                elif oc == "call":
                    callee = op.attr_called("to_apply")
                    if callee:
                        walk(callee, mult, stack)
                elif oc == "conditional":
                    for callee in op.called():
                        walk(callee, mult, stack)
                # to_apply of reduce/map/scatter is approximated at the op
                # level (1 flop per application) -- not walked.

        if self.entry is not None:
            walk(self.entry, 1.0, ())
        return {
            "flops": totals.flops,
            "hbm_bytes": totals.hbm_bytes,
            "collective_bytes": totals.collective_bytes,
            "collective_by_op": dict(by_op),
            "transcendentals": totals.transcendentals,
            "diagnostics": diags,
        }


def analyze(text: str) -> dict:
    """Parse `text` and return trip-count-multiplied module totals."""
    return ModuleCost(text).analyze()
