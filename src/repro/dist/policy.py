"""Memory-aware serve-layout policy: pick weight placement from measured HBM.

FLight's resource manager places FL work on heterogeneous workers using
cheap heuristics over measured capacity (paper SSIII-B).  This module is
the serving analogue for the SPMD stack: per (arch x shape x mesh) cell it
picks HOW weights are laid out across the mesh from the program's own
memory numbers, replacing the hardcoded `n_params * 2 / TP < 8 GB` check
that used to live in launch/dryrun.py.

Candidate layouts (dist/sharding.py::SERVE_LAYOUTS, most stationary
first):

  stationary -- SERVE_RULES: weights tensor-parallel over "model" only,
                replicated over "data"; zero weight traffic per step.
  hybrid     -- HYBRID_SERVE_RULES: body weights stationary, but the
                embedding / lm_head tables (logical "vocab"/"embed" dims)
                also shard over "data"; for models whose body fits but
                whose vocab tables blow the budget.
  fsdp       -- DEFAULT_RULES: fully-sharded weights (the training
                layout); always fits, pays weight all-gathers per step.

Since the CacheSpec layer (models/cache.py) the policy scores the full
(weight layout x cache spec) PRODUCT for serve cells: each weight layout
is paired with every CACHE_SPEC_CANDIDATES entry (head/bf16, ring/bf16,
head/int8, ring/int8), plus chunked-prefill variants for long-prompt
prefill cells.  int8 cache reads are charged at bf16-equivalent bytes in
the step-time proxy, so quantization is a FIT tool (smaller residency)
rather than a modeled speed win, and the historical head/bf16 convention
wins whenever it fits.

Decision procedure (`decide`): every candidate gets a CandidateEval with
predicted peak per-device HBM and predicted step time.  A candidate is
FEASIBLE when `hbm_bytes <= DEVICE_HBM_BYTES * margin` (margin defaults to
0.9: leave 10% headroom for allocator fragmentation + collective
scratch).  Among feasible candidates the fastest predicted step wins
(ties: the more stationary layout, i.e. earlier in SERVE_LAYOUTS order).
If NOTHING fits -- the huge-MoE case -- the policy falls back to the
candidate with the smallest peak (fsdp in practice) and flags
`fits=False`.

Evaluators (where the numbers come from):

  * eval_from_compiled(...)  -- XLA ground truth: `memory_analysis` of an
    AOT-compiled program (launch/dryrun.py compiles every candidate and
    caches the probes in the artifact JSON), step time from the
    trip-count-aware hlo_cost roofline.
  * analytic_eval(...)       -- no compile: exact per-device param / cache
    / input bytes from the ParamDef tree resolved through the candidate's
    RuleSet, plus an activation-workspace term; used by launch/serve.py
    and ServeLoop where compiling three layouts first is not acceptable.

EXPERIMENTS.md ("Layout policy decisions") tabulates the chosen layout and
headroom for every cell of the committed dryrun sweep.
"""
from __future__ import annotations

import dataclasses
import math

from repro.dist.sharding import (SERVE_LAYOUTS, logical_to_mesh_spec,
                                 serve_layout_rules)

#: Per-device HBM of the modeled chip (v5e-class, 16 GB; see the hardware
#: constants in dist/hlo_analysis.py).
DEVICE_HBM_BYTES = 16e9

#: Fraction of DEVICE_HBM_BYTES a layout may use before it is infeasible.
DEFAULT_MARGIN = 0.9


# ---------------------------------------------------------------------------
# Evaluations
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CandidateEval:
    """Predicted peak HBM + step time for one (weight layout x cache
    spec) candidate.  `cache` is a models/cache.CacheSpec name
    ("ring/int8", ...; "" = the model's default spec / no cache);
    `chunked` marks the chunked-prefill variant that streams a long
    prompt through bounded chunks instead of one-shot prefill."""
    layout: str
    hbm_bytes: float          # peak per-device HBM the program needs
    step_time_s: float        # predicted step time (roofline bound)
    source: str = "analytic"  # "xla" (compiled memory_analysis) | "analytic"
    detail: dict = dataclasses.field(default_factory=dict)
    cache: str = ""
    chunked: bool = False

    @property
    def key(self) -> str:
        """Unique candidate id: layout[+cache][+chunked]."""
        k = self.layout
        if self.cache:
            k += f"+{self.cache}"
        if self.chunked:
            k += "+chunked"
        return k

    def as_dict(self) -> dict:
        return {"layout": self.layout, "hbm_bytes": self.hbm_bytes,
                "hbm_gb": round(self.hbm_bytes / 1e9, 3),
                "step_time_s": self.step_time_s, "source": self.source,
                **({"cache": self.cache} if self.cache else {}),
                **({"chunked": True} if self.chunked else {}),
                **({"detail": self.detail} if self.detail else {})}


def peak_hbm_bytes(memory_analysis: dict) -> float:
    """Peak per-device HBM from an XLA `memory_analysis` dict.

    arguments + temporaries + the non-aliased slice of the outputs
    (donated/aliased outputs live in their argument's buffer).
    """
    args = memory_analysis.get("argument_size_in_bytes", 0)
    temp = memory_analysis.get("temp_size_in_bytes", 0)
    out = memory_analysis.get("output_size_in_bytes", 0)
    alias = memory_analysis.get("alias_size_in_bytes", 0)
    return float(args + temp + max(out - alias, 0))


def eval_from_compiled(layout: str, memory_analysis: dict,
                       roofline: dict, *, cache: str = "",
                       chunked: bool = False) -> CandidateEval:
    """CandidateEval from dryrun-grade numbers (XLA memory_analysis +
    hlo_cost roofline dict with a `bound_s` key)."""
    return CandidateEval(
        layout=layout,
        hbm_bytes=peak_hbm_bytes(memory_analysis),
        step_time_s=float(roofline.get("bound_s", 0.0)),
        source="xla",
        detail={"memory_analysis": dict(memory_analysis)},
        cache=cache, chunked=chunked)


# ---------------------------------------------------------------------------
# Analytic evaluator (no compile)
# ---------------------------------------------------------------------------

def _def_leaves(defs):
    import jax
    from repro.models.param import is_def
    return jax.tree.leaves(defs, is_leaf=is_def)


def sharded_bytes(defs, mesh, rules) -> float:
    """Exact per-device bytes of a ParamDef tree laid out under `rules`."""
    sizes = {str(k): int(v) for k, v in dict(mesh.shape).items()}
    total = 0.0
    for d in _def_leaves(defs):
        spec = logical_to_mesh_spec(d.logical_axes, d.shape, mesh, rules)
        shard = 1
        for entry in spec:
            if entry is None:
                continue
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                shard *= sizes.get(ax, 1)
        total += (d.dtype.itemsize * math.prod(d.shape)) / shard
    return total


#: Tokens per chunk of the chunked-prefill variant (matches the chunk
#: size launch/dryrun.py compiles for long-prompt cells).
CHUNK_TOKENS = 4096

#: CacheSpec candidates the serve policy sweeps per weight layout, in
#: preference order: the historical head-sharded bf16 convention first,
#: then seq-sharded ring, then the int8 variants (a FIT tool, not a
#: modeled speed win -- int8 cache reads are charged at bf16-equivalent
#: bytes so bf16 wins whenever both fit).
CACHE_SPEC_CANDIDATES = ("head/bf16", "ring/bf16", "head/int8", "ring/int8")


def _cache_bytes(model, shape, mesh, rules, cache_spec):
    """(resident_bytes, stream_bytes) of the decode/prefill cache under
    `cache_spec` ("" / None = the model's config default).  stream_bytes
    is what the attention must move per step, charged at bf16 width even
    for int8 caches (dequant runs at full width in-register; quantizing
    shrinks RESIDENCY, which is the fit story, not arithmetic traffic)."""
    if shape.kind not in ("decode", "prefill") or model._cache_defs is None:
        return 0.0, 0.0
    B, S = shape.global_batch, shape.seq_len
    if cache_spec and model.supports_cache_spec:
        from repro.models.cache import CacheSpec
        spec = CacheSpec.parse(cache_spec)
        resident = sharded_bytes(model.cache_defs(B, S, spec=spec),
                                 mesh, rules)
        if spec.quantized:
            bf16 = dataclasses.replace(spec, dtype="bf16")
            stream = sharded_bytes(model.cache_defs(B, S, spec=bf16),
                                   mesh, rules)
        else:
            stream = resident
        return resident, stream
    resident = sharded_bytes(model.cache_defs(B, S), mesh, rules)
    return resident, resident


def analytic_eval(model, shape, mesh, layout: str, *,
                  cache_spec: str | None = None, chunked: bool = False,
                  hbm_bw: float | None = None) -> CandidateEval:
    """Compile-free CandidateEval: param/cache/input bytes from the
    ParamDef tree resolved through the (layout, cache_spec) candidate's
    RuleSet, plus a 2-deep activation workspace, with a
    weight/cache-streaming step-time proxy.

    The step-time proxy charges every byte the device must READ each step
    (stationary weights stream from local HBM; fsdp weights must first be
    gathered -- charged at ICI bandwidth, which is what makes stationary
    win whenever it fits).  Prefill counts the produced cache against
    peak too: the one-shot prefill entry RETURNS the cache, and outputs
    don't alias any argument there.  `chunked` models the chunked-prefill
    variant: peak activations shrink to one CHUNK_TOKENS chunk, but the
    weights stream once per chunk, so one-shot prefill stays preferred
    whenever it fits.
    """
    from repro.dist.hlo_analysis import HBM_BW, ICI_BW
    hbm_bw = hbm_bw or HBM_BW
    rules = serve_layout_rules(layout)
    stationary = serve_layout_rules("stationary")

    p_bytes = sharded_bytes(model.param_defs(), mesh, rules)
    in_bytes = sharded_bytes(model.input_defs(shape), mesh, rules)
    c_bytes, c_stream = _cache_bytes(model, shape, mesh, rules, cache_spec)
    if shape.kind == "prefill" and not cache_spec:
        # historical baseline: prefill peak modeled without the cache
        # output (kept so the default 3-layout table is stable); product
        # candidates carry a cache_spec and count it.
        c_bytes = c_stream = 0.0
    # activation workspace: ~2 live (tokens/dev, d_model) bf16 copies
    sizes = {str(k): int(v) for k, v in dict(mesh.shape).items()}
    data_deg = sizes.get("data", 1) * sizes.get("pod", 1)
    toks = shape.global_batch * (1 if shape.kind == "decode" else
                                 shape.seq_len)
    n_chunks = 1
    peak_toks = toks
    if chunked:
        n_chunks = max(1, math.ceil(shape.seq_len / CHUNK_TOKENS))
        peak_toks = shape.global_batch * min(CHUNK_TOKENS, shape.seq_len)
    act_peak = 2.0 * (peak_toks / max(data_deg, 1)) * \
        getattr(model.cfg, "d_model", 1) * 2
    act_total = 2.0 * (toks / max(data_deg, 1)) * \
        getattr(model.cfg, "d_model", 1) * 2

    # weight bytes that must be gathered per step to run stationary-style
    # compute (0 for stationary by construction); chunked prefill streams
    # (and re-gathers) the weights once per chunk.
    p_stationary = sharded_bytes(model.param_defs(), mesh, stationary)
    gather_bytes = max(p_stationary - p_bytes, 0.0)
    step = (p_bytes * n_chunks + c_stream + act_total) / hbm_bw \
        + gather_bytes * n_chunks / ICI_BW
    return CandidateEval(
        layout=layout,
        hbm_bytes=p_bytes + c_bytes + in_bytes + act_peak,
        step_time_s=step,
        source="analytic",
        detail={"param_bytes": p_bytes, "cache_bytes": c_bytes,
                "cache_stream_bytes": c_stream,
                "activation_bytes": act_peak,
                "gather_bytes_per_step": gather_bytes,
                "n_chunks": n_chunks},
        cache=cache_spec or "", chunked=chunked)


# ---------------------------------------------------------------------------
# Decision
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayoutDecision:
    """The chosen (layout, cache_spec, chunked) plus the full
    per-candidate scoring table.  `cache_spec`/`chunked` default to
    ""/False so pre-CacheSpec decisions (and tests constructing the
    dataclass positionally) keep working."""
    layout: str
    fits: bool                      # chosen candidate under budget*margin?
    budget_bytes: float
    margin: float
    evals: tuple                    # CandidateEval, in evaluation order
    reason: str
    cache_spec: str = ""            # "" = the model's config default
    chunked: bool = False

    @property
    def rules(self):
        return serve_layout_rules(self.layout)

    @property
    def key(self) -> str:
        k = self.layout
        if self.cache_spec:
            k += f"+{self.cache_spec}"
        if self.chunked:
            k += "+chunked"
        return k

    @property
    def chosen(self) -> CandidateEval:
        for e in self.evals:
            if e.key == self.key:
                return e
        for e in self.evals:           # pre-CacheSpec decision records
            if e.layout == self.layout:
                return e
        raise KeyError(self.key)

    def headroom_bytes(self, e: CandidateEval | None = None) -> float:
        e = e or self.chosen
        return self.budget_bytes * self.margin - e.hbm_bytes

    def as_dict(self) -> dict:
        return {
            "layout": self.layout, "fits": self.fits,
            **({"cache_spec": self.cache_spec} if self.cache_spec else {}),
            **({"chunked": True} if self.chunked else {}),
            "budget_gb": round(self.budget_bytes / 1e9, 2),
            "margin": self.margin,
            "headroom_gb": round(self.headroom_bytes() / 1e9, 3),
            "reason": self.reason,
            "candidates": [e.as_dict() for e in self.evals],
        }


def decide(evals, *, budget_bytes: float = DEVICE_HBM_BYTES,
           margin: float = DEFAULT_MARGIN) -> LayoutDecision:
    """Headroom-aware scoring: feasible = peak HBM <= budget*margin; the
    fastest feasible candidate wins (ties: first in `evals` order, which
    callers pass most-stationary-first, default-cache-first).  With no
    feasible candidate the smallest peak wins and `fits=False` (huge-MoE
    fallback)."""
    evals = tuple(evals)
    if not evals:
        raise ValueError("no candidate evaluations")
    cap = budget_bytes * margin
    feasible = [e for e in evals if e.hbm_bytes <= cap]
    if feasible:
        best = min(feasible, key=lambda e: e.step_time_s)
        reason = (f"{best.key}: peak {best.hbm_bytes/1e9:.2f} GB <= "
                  f"{cap/1e9:.2f} GB budget "
                  f"(headroom {(cap-best.hbm_bytes)/1e9:.2f} GB), fastest "
                  f"feasible step {best.step_time_s:.3g}s of "
                  f"{len(feasible)}/{len(evals)} feasible")
        return LayoutDecision(best.layout, True, budget_bytes, margin,
                              evals, reason, cache_spec=best.cache,
                              chunked=best.chunked)
    best = min(evals, key=lambda e: e.hbm_bytes)
    reason = (f"no layout fits under {cap/1e9:.2f} GB "
              f"({margin:.0%} of {budget_bytes/1e9:.0f} GB); falling back "
              f"to min-peak {best.key} at {best.hbm_bytes/1e9:.2f} GB "
              f"(over by {(best.hbm_bytes-cap)/1e9:.2f} GB)")
    return LayoutDecision(best.layout, False, budget_bytes, margin,
                          evals, reason, cache_spec=best.cache,
                          chunked=best.chunked)


def choose_serve_layout(evaluate, *, layouts=None,
                        budget_bytes: float = DEVICE_HBM_BYTES,
                        margin: float = DEFAULT_MARGIN) -> LayoutDecision:
    """Evaluate every candidate layout with `evaluate(name) ->
    CandidateEval` (most-stationary-first order) and decide."""
    layouts = list(layouts) if layouts is not None else list(SERVE_LAYOUTS)
    return decide([evaluate(name) for name in layouts],
                  budget_bytes=budget_bytes, margin=margin)


def serve_product_candidates(model, shape):
    """(layout, cache_spec, chunked) product candidates for one serve
    cell, in preference order: layouts most-stationary-first; within a
    layout the historical head/bf16 convention first, exotic specs after;
    chunked-prefill variants last (they pay n_chunks weight re-reads).

    Cache specs only enter the product for cells that HAVE a spec'able
    cache (decode/prefill on transformer families).  Chunked prefill is
    excluded for VLM-stub models (the patch_embeds prefix assumes
    one-shot prefill) and enc-dec archs (cross-attention frames)."""
    has_cache = (shape.kind in ("decode", "prefill")
                 and model._cache_defs is not None
                 and model.supports_cache_spec)
    chunk_ok = (shape.kind == "prefill" and has_cache
                and getattr(model.cfg, "frontend", "none") == "none"
                and not model.cfg.is_encdec
                and shape.seq_len > CHUNK_TOKENS)
    out = []
    for layout in SERVE_LAYOUTS:
        if not has_cache:
            out.append((layout, None, False))
            continue
        for spec in CACHE_SPEC_CANDIDATES:
            out.append((layout, spec, False))
    if chunk_ok:
        for layout in SERVE_LAYOUTS:
            for spec in CACHE_SPEC_CANDIDATES:
                out.append((layout, spec, True))
    return out


def analytic_serve_decision(model, shape, mesh, *,
                            budget_bytes: float = DEVICE_HBM_BYTES,
                            margin: float = DEFAULT_MARGIN) -> LayoutDecision:
    """Compile-free decision for serve launchers (serve.py / ServeLoop):
    scores the full (weight layout x cache spec [x chunked]) product."""
    evals = [analytic_eval(model, shape, mesh, layout, cache_spec=spec,
                           chunked=ch)
             for layout, spec, ch in serve_product_candidates(model, shape)]
    return decide(evals, budget_bytes=budget_bytes, margin=margin)
