"""Logical-axis -> mesh-axis sharding resolution.

Models annotate tensors with LOGICAL axis names ("batch", "embed", "ffn",
"heads", ...).  A RuleSet maps each logical name to an ordered list of
candidate mesh axes; `logical_to_mesh_spec` resolves one tensor's logical
axes against a mesh, enforcing:

  * divisibility  -- a mesh axis is only used when the dim size divides
                     evenly; otherwise the next candidate (or None) is used;
  * axis-used-once -- each mesh axis appears at most once per tensor;
                     priority dims (heads/kv_heads) claim first, then
                     position order breaks ties;
  * explicit axes -- a logical entry may itself be a tuple of MESH axis
                     names (e.g. ("model",) for sequence/context
                     parallelism), resolved verbatim before any rule.

Three rule sets ship here:
  DEFAULT_RULES -- FSDP ("data") x TP ("model") training layout; batch
                   stacks over every pod+data axis that fits.
  ISLAND_RULES  -- the FL layout: the `pod` axis is reserved for the
                   island ("island" -> pod) so batch shards over data only.
  SERVE_RULES   -- stationary TP-only weights (no FSDP): "embed" stays
                   replicated, everything tensor-parallel goes to "model".

`constrain(x, logical_axes)` applies `with_sharding_constraint` against the
AMBIENT mesh (the `with mesh:` context the caller lowered under) and the
ambient rules (`use_rules`).  With no ambient mesh it is a no-op, so model
code runs unchanged in single-device CPU tests.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import warnings

import jax
from jax.sharding import NamedSharding, PartitionSpec


class ShardingFallbackWarning(UserWarning):
    """A PRIORITY logical dim (heads / kv_heads) could not claim its mesh
    axis (divisibility or axis-used-once failed) and the dim fell back to
    replication.  This is exactly the footgun that silently replicated a
    432 GB/dev decode cache for qwen1.5-4b (20 kv heads on a 16-wide
    model axis): the resolution still proceeds -- the warning + the
    FallbackRecord in the caller's `report` make it visible."""


@dataclasses.dataclass(frozen=True)
class FallbackRecord:
    """One recorded resolution fallback (see logical_to_mesh_spec)."""
    logical: str                  # logical dim name, e.g. "kv_heads"
    dim: int                      # tensor dim size that failed to shard
    shape: tuple                  # full tensor shape
    candidates: tuple             # mesh axes the rule offered
    reason: str                   # "indivisible" | "axis_taken"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


# warn once per distinct (logical, dim, mesh axis sizes) -- resolution
# runs per tensor leaf per trace and would otherwise emit thousands of
# identical warnings
_warned_fallbacks: set = set()


# ---------------------------------------------------------------------------
# Rule sets
# ---------------------------------------------------------------------------

class RuleSet(dict):
    """logical axis name -> ordered tuple of candidates.

    A candidate is either a mesh axis name (str) or a tuple of mesh axis
    names to be stacked greedily (longest divisible prefix wins).
    `priority` lists logical dims that claim their mesh axes before the
    rest of the tensor (attention heads beat ffn for the "model" axis).
    """

    def __init__(self, mapping=(), priority=("heads", "kv_heads"), **kw):
        super().__init__(mapping, **kw)
        self.priority = tuple(priority)

    def replacing(self, **kw) -> "RuleSet":
        new = RuleSet(self, priority=self.priority)
        new.update(kw)
        return new


DEFAULT_RULES = RuleSet({
    "batch": (("pod", "data"),),
    "island": ("pod",),
    "layers": (),                    # scan axis: never sharded
    "embed": ("data",),              # FSDP shard of the d_model dim
    "embed_tp": ("model", "data"),   # output-projection d_model dim
    "ffn": ("model",),
    "expert_ffn": ("model",),
    "experts": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "vocab": ("model", "data"),
    "ssm_inner": ("model",),
    "lru_width": ("model",),
})

# FL islands: `pod` belongs to the island axis, batch must not touch it.
ISLAND_RULES = DEFAULT_RULES.replacing(batch=("data",))

# Serving: stationary weights, tensor-parallel only (no FSDP over "data").
SERVE_RULES = DEFAULT_RULES.replacing(
    embed=(), embed_tp=("model",), vocab=("model",))

# Hybrid serving: body weights stay stationary (TP-only, like SERVE_RULES)
# but the embedding / lm_head tables also shard over "data".  Only those
# two tables carry a "vocab" logical dim, so widening the vocab rule to
# the ("model", "data") stack shards exactly them and nothing else --
# halfway house for models whose body fits stationary but whose vocab
# tables blow the per-device budget.
HYBRID_SERVE_RULES = SERVE_RULES.replacing(vocab=(("model", "data"),))

#: serve layout name -> RuleSet, in decreasing weight-stationarity.  The
#: layout POLICY (dist/policy.py) picks between these per (arch x shape x
#: mesh) from memory_analysis numbers; this factory is the single place
#: that names them.
SERVE_LAYOUTS = {
    "stationary": SERVE_RULES,
    "hybrid": HYBRID_SERVE_RULES,
    "fsdp": DEFAULT_RULES,
}


def serve_layout_rules(layout: str) -> RuleSet:
    """RuleSet for a named serve layout (see SERVE_LAYOUTS)."""
    try:
        return SERVE_LAYOUTS[layout]
    except KeyError:
        raise KeyError(f"unknown serve layout '{layout}'; "
                       f"known: {sorted(SERVE_LAYOUTS)}") from None


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------

def _mesh_sizes(mesh) -> dict:
    return {str(k): int(v) for k, v in dict(mesh.shape).items()}


def logical_to_mesh_spec(logical_axes, shape, mesh,
                         rules: RuleSet | None = None,
                         report: list | None = None) -> PartitionSpec:
    """Resolve one tensor's logical axes to a PartitionSpec for `mesh`.

    logical_axes: per-dim entries -- a logical name, None, or an explicit
        tuple of mesh axis names.  Must match len(shape).
    report: optional list; a FallbackRecord is appended for every PRIORITY
        dim that had a live candidate axis but resolved to None
        (replication).  A ShardingFallbackWarning is emitted once per
        distinct (logical, dim, mesh) either way.
    """
    rules = DEFAULT_RULES if rules is None else rules
    if len(logical_axes) != len(shape):
        raise ValueError(f"rank mismatch: axes {logical_axes} vs "
                         f"shape {shape}")
    sizes = _mesh_sizes(mesh)
    used: set[str] = set()
    entries: list = [None] * len(shape)

    def claim_stack(names, dim):
        """Longest prefix of `names` (present, unused) whose cumulative
        product divides `dim`."""
        picked, prod = [], 1
        for nm in names:
            if nm not in sizes or nm in used:
                continue
            if dim % (prod * sizes[nm]) == 0:
                picked.append(nm)
                prod *= sizes[nm]
            else:
                break
        return picked

    def emit(picked):
        for nm in picked:
            used.add(nm)
        if not picked:
            return None
        return picked[0] if len(picked) == 1 else tuple(picked)

    def resolve_rule(name, dim):
        for cand in rules.get(name, ()):
            if isinstance(cand, (tuple, list)):
                picked = claim_stack(cand, dim)
                if picked:
                    return emit(picked)
            elif cand in sizes and cand not in used and dim % sizes[cand] == 0:
                return emit([cand])
        return None

    def note_fallback(name, dim):
        """A priority dim resolved to None: was a candidate axis live?
        Axes claimed by an explicit pass-0 tuple don't count -- the
        caller chose that placement (e.g. the ring cache deliberately
        gives "model" to the seq dim instead of kv_heads)."""
        cands, reason = [], None
        for cand in rules.get(name, ()):
            for ax in (cand if isinstance(cand, (tuple, list)) else (cand,)):
                if ax not in sizes or sizes[ax] <= 1 or ax in explicit:
                    continue
                cands.append(ax)
                reason = "axis_taken" if ax in used else "indivisible"
        if reason is None:
            return
        rec = FallbackRecord(name, dim, tuple(shape), tuple(cands), reason)
        if report is not None:
            report.append(rec)
        key = (name, dim, reason, tuple(sorted(sizes.items())))
        if key not in _warned_fallbacks:
            _warned_fallbacks.add(key)
            warnings.warn(
                f"priority dim '{name}' (size {dim}, tensor {tuple(shape)}) "
                f"cannot shard over {cands} ({reason}: "
                f"{ {a: sizes[a] for a in cands} }) and REPLICATES -- "
                f"consider a seq-sharded ring cache spec "
                f"(models/cache.py) for decode caches",
                ShardingFallbackWarning, stacklevel=3)

    # Pass 0: explicit mesh-axis tuples bind first (caller knows best).
    explicit: set[str] = set()
    for i, ax in enumerate(logical_axes):
        if isinstance(ax, (tuple, list)):
            entries[i] = emit(claim_stack(ax, shape[i]))
            explicit.update(used)
    # Pass 1: priority logical dims; Pass 2: everything else, in position
    # order.
    for wave in (rules.priority, None):
        for i, ax in enumerate(logical_axes):
            if not isinstance(ax, str) or entries[i] is not None:
                continue
            if wave is not None and ax not in wave:
                continue
            if wave is None and ax in rules.priority:
                continue
            entries[i] = resolve_rule(ax, shape[i])
            if wave is not None and entries[i] is None:
                note_fallback(ax, shape[i])
    return PartitionSpec(*entries)


def mesh_axis_size(name: str) -> int:
    """Size of `name` in the ambient mesh (1 when absent / no mesh)."""
    mesh = _ambient_mesh()
    if mesh is None:
        return 1
    return _mesh_sizes(mesh).get(name, 1)


def spec_tree_for(defs, mesh, rules: RuleSet | None = None):
    """ParamDef tree -> NamedSharding tree (jit in_shardings)."""
    def leaf(d):
        spec = logical_to_mesh_spec(d.logical_axes, d.shape, mesh, rules)
        return NamedSharding(mesh, spec)
    return jax.tree.map(leaf, defs,
                        is_leaf=lambda x: hasattr(x, "logical_axes"))


# ---------------------------------------------------------------------------
# Ambient mesh + rules (for constrain() inside model code)
# ---------------------------------------------------------------------------

_state = threading.local()


def current_rules() -> RuleSet:
    return getattr(_state, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def use_rules(rules: RuleSet):
    prev = current_rules()
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def _ambient_mesh():
    """The mesh of the enclosing `with mesh:` / `use_mesh` context."""
    try:                                    # classic thread-resources mesh
        from jax._src import mesh as _mesh_lib
        m = _mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    try:                                    # newer explicit-mesh API
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def constrain(x, logical_axes):
    """with_sharding_constraint against the ambient mesh + rules.

    No-op when there is no ambient mesh (CPU unit tests, eager code).
    """
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    spec = logical_to_mesh_spec(logical_axes, x.shape, mesh, current_rules())
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Version compat
# ---------------------------------------------------------------------------

def abstract_mesh(axis_sizes, axis_names):
    """AbstractMesh across jax versions: (sizes, names) vs ((name, size),)."""
    try:
        return jax.sharding.AbstractMesh(tuple(axis_sizes),
                                         tuple(axis_names))
    except TypeError:
        return jax.sharding.AbstractMesh(
            tuple(zip(axis_names, axis_sizes)))
