# Pallas TPU kernels for the framework's compute hot-spots.
# Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
# wrapper with interpret fallback), ref.py (pure-jnp oracle).
#
#   fed_agg         -- K-way weighted model aggregation (the FLight exchange)
#   quant8          -- per-block int8 quantise/dequantise (compression)
#   flash_attention -- causal/windowed GQA flash attention (prefill hot-spot)
#   linrec          -- blocked diagonal linear recurrence (mamba / RG-LRU)
