"""Pallas API compat shared by all kernels in this package."""
from jax.experimental.pallas import tpu as pltpu

# TPUCompilerParams was renamed to CompilerParams in newer jax releases
CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams
