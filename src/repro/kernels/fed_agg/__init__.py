from repro.kernels.fed_agg.ops import fed_agg, fed_agg_tree
