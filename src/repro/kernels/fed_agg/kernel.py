"""Pallas TPU kernel: K-way weighted aggregation (the FLight merge).

Computes out[n] = sum_k w[k] * x[k, n] over a stacked (K, N) weight matrix
in fp32, streaming N through VMEM in (K, BLOCK) tiles.  One pass over HBM:
arithmetic intensity ~K flops/2K bytes, i.e. HBM-bound -- the kernel's job
is to keep the single pass (XLA's unfused weighted sum reads the stack once
per island when K is traced per-element).

Tiling: N is reshaped to (N // BLOCK_N, BLOCK_N) with BLOCK_N a multiple of
128 (lane width); K rides whole in the sublane dim (islands are few).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 2048


def _fed_agg_kernel(w_ref, x_ref, o_ref):
    # w_ref: (K, 1) fp32; x_ref: (K, BLOCK_N); o_ref: (1, BLOCK_N)
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)          # (K, 1)
    acc = jnp.sum(x * w, axis=0, keepdims=True)  # (1, BLOCK_N)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def fed_agg_2d(stacked, weights, *, interpret: bool = False,
               block_n: int = BLOCK_N):
    """stacked: (K, N) any float dtype; weights: (K,) fp32 -> (N,)."""
    K, N = stacked.shape
    pad = (-N) % block_n
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
    Np = N + pad
    out = pl.pallas_call(
        _fed_agg_kernel,
        grid=(Np // block_n,),
        in_specs=[
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, block_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Np), stacked.dtype),
        interpret=interpret,
    )(weights.reshape(K, 1).astype(jnp.float32), stacked)
    return out[0, :N]
