"""Public API for the fed_agg kernel: TPU pallas path / CPU interpret /
jnp reference, switchable; pytree convenience wrapper."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fed_agg.kernel import fed_agg_2d
from repro.kernels.fed_agg.ref import fed_agg_2d_ref


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def fed_agg(stacked, weights, *, impl: str = "auto"):
    """stacked (K, ...) -> weighted sum over axis 0 (fp32 accumulate)."""
    K = stacked.shape[0]
    flat = stacked.reshape(K, -1)
    if impl == "ref":
        out = fed_agg_2d_ref(flat, weights)
    else:
        out = fed_agg_2d(flat, weights, interpret=_use_interpret())
    return out.reshape(stacked.shape[1:])


def fed_agg_tree(param_list, weights, *, impl: str = "auto"):
    """Aggregate a list of parameter pytrees into one (kernel-backed)."""
    w = jnp.asarray(weights, jnp.float32)

    def merge(*leaves):
        return fed_agg(jnp.stack(leaves), w, impl=impl)

    return jax.tree.map(merge, *param_list)
