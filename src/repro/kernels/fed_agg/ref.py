"""Pure-jnp oracle for the fed_agg kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fed_agg_2d_ref(stacked, weights):
    """out[n] = sum_k w[k] * x[k, n], fp32 accumulate."""
    acc = jnp.einsum("kn,k->n", stacked.astype(jnp.float32),
                     weights.astype(jnp.float32))
    return acc.astype(stacked.dtype)


def fed_agg_tree_ref(param_list, weights):
    w = jnp.asarray(weights, jnp.float32)

    def merge(*leaves):
        stack = jnp.stack([l.astype(jnp.float32) for l in leaves])
        return jnp.einsum("k...,k->...", stack, w).astype(leaves[0].dtype)

    return jax.tree.map(merge, *param_list)
