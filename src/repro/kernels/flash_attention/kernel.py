"""Pallas TPU kernel: causal / sliding-window GQA flash attention (fwd).

TPU adaptation of the FlashAttention blocking:
  grid = (batch, q_heads, nq, nkv) with the kv dimension SEQUENTIAL
  ('arbitrary'); q/k/v stream through VMEM in (BQ, D) / (BK, D) tiles, the
  online-softmax stats (m, l) and the (BQ, D) accumulator live in VMEM
  scratch across kv steps.  GQA is an index_map: q head h reads kv head
  h // group.  Causal and sliding-window blocks that are fully masked are
  SKIPPED via pl.when (true compute skipping, unlike a masked XLA einsum --
  this is the kernel's roofline win for the prefill cells, ~halving the
  attention FLOPs at 32k).

Block sizes default to (BQ, BK) = (512, 512): VMEM per step is q 512x128 +
k/v 2x512x128 bf16 (~0.4 MB) + fp32 acc 512x128 (0.25 MB), comfortably
inside ~16 MB VMEM with double buffering; MXU tiles are 128-aligned.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

DEFAULT_BQ = 512
DEFAULT_BK = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, window: int, bq: int, bk: int, nkv: int,
                  scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = iq * bq
    k_lo = ik * bk
    # block-level skip: strictly-above-diagonal (causal) or out-of-window
    live = jnp.bool_(True)
    if causal:
        live = jnp.logical_and(live, k_lo <= q_lo + bq - 1)
    if window:
        live = jnp.logical_and(live, k_lo + bk - 1 > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)           # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (BQ, BK)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                            # (BQ, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new

    @pl.when(ik == nkv - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention_bhtd(q, k, v, *, causal: bool = True, window: int = 0,
                         bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                         interpret: bool = False):
    """q: (B, H, T, D); k/v: (B, Hkv, S, D).  Returns (B, H, T, D)."""
    B, H, T, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = H // Hkv
    bq = min(bq, T)
    bk = min(bk, S)
    assert T % bq == 0 and S % bk == 0, (T, bq, S, bk)
    nq, nkv = T // bq, S // bk
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_flash_kernel, causal=causal, window=window,
                               bq=bq, bk=bk, nkv=nkv, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
