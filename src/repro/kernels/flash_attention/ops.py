"""Public flash-attention API ((B,T,H,D) layout used by the models)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhtd
from repro.kernels.flash_attention.ref import attention_ref


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    impl: str = "auto", bq: int = 512, bk: int = 512):
    """q: (B, T, H, D); k/v: (B, S, Hkv, D) -> (B, T, H, D)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if impl == "ref":
        out = attention_ref(qt, kt, vt, causal=causal, window=window)
    else:
        out = flash_attention_bhtd(qt, kt, vt, causal=causal, window=window,
                                   bq=bq, bk=bk,
                                   interpret=_use_interpret())
    return out.transpose(0, 2, 1, 3)
