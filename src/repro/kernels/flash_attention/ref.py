"""Pure-jnp oracle for the flash_attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, H, T, D); k/v: (B, Hkv, S, D) -> (B, H, T, D).  Exact softmax
    attention with GQA head grouping, fp32 math."""
    B, H, T, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = H // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, T, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgtd,bhsd->bhgts", qf, kf) / math.sqrt(D)
    qpos = jnp.arange(T)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgts,bhsd->bhgtd", p, vf)
    return o.reshape(B, H, T, D).astype(q.dtype)
