from repro.kernels.linrec.ops import linrec
