"""Pallas TPU kernel: blocked diagonal linear recurrence
    h_t = a_t * h_{t-1} + b_t        (h, a, b: (..., D) elementwise)

Shared by the mamba selective scan (D = d_inner*N flattened) and the RG-LRU
(D = lru_width).  TPU adaptation of the fused CUDA selective-scan: the
sequence is streamed through VMEM in (BT, BD) tiles with the carry h held
in VMEM scratch across T tiles, so the (B, T, D) state trajectory never
round-trips HBM more than once.  grid = (B, D//BD, T//BT) with T
SEQUENTIAL; the in-tile recurrence is a log-depth blelloch-style composite
(associative (a,b) combine) to keep the VPU busy instead of a scalar loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

DEFAULT_BT = 256
DEFAULT_BD = 512


def _linrec_kernel(a_ref, b_ref, o_ref, h_scr, *, bt: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0].astype(jnp.float32)     # (BT, BD)
    b = b_ref[0].astype(jnp.float32)

    # in-tile prefix composition: after the loop, A[t] = prod a[..t],
    # B[t] = sum_j (prod_{j<i<=t} a[i]) b[j]  -- log2(BT) doubling steps.
    A, Bc = a, b
    shift = 1
    while shift < bt:
        A_prev = jnp.concatenate(
            [jnp.ones((shift, A.shape[1]), A.dtype), A[:-shift]], axis=0)
        B_prev = jnp.concatenate(
            [jnp.zeros((shift, Bc.shape[1]), Bc.dtype), Bc[:-shift]], axis=0)
        Bc = Bc + A * B_prev
        A = A * A_prev
        shift *= 2

    h0 = h_scr[...]                      # (1, BD)
    hs = A * h0 + Bc                     # (BT, BD)
    o_ref[0] = hs.astype(o_ref.dtype)
    h_scr[...] = hs[-1:]


@functools.partial(jax.jit, static_argnames=("bt", "bd", "interpret"))
def linrec_btd(a, b, *, bt: int = DEFAULT_BT, bd: int = DEFAULT_BD,
               interpret: bool = False):
    """a, b: (B, T, D) -> hs (B, T, D) with h_t = a_t h_{t-1} + b_t, h_0=b_0."""
    B, T, D = a.shape
    bt = min(bt, T)
    bd = min(bd, D)
    assert T % bt == 0 and D % bd == 0, (T, bt, D, bd)

    kernel = functools.partial(_linrec_kernel, bt=bt)
    return pl.pallas_call(
        kernel,
        grid=(B, D // bd, T // bt),
        in_specs=[
            pl.BlockSpec((1, bt, bd), lambda ib, jd, it: (ib, it, jd)),
            pl.BlockSpec((1, bt, bd), lambda ib, jd, it: (ib, it, jd)),
        ],
        out_specs=pl.BlockSpec((1, bt, bd), lambda ib, jd, it: (ib, it, jd)),
        out_shape=jax.ShapeDtypeStruct((B, T, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
