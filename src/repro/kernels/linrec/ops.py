"""Public linrec API: any (..., T, D)-broadcastable diagonal recurrence."""
from __future__ import annotations

import jax

from repro.kernels.linrec.kernel import linrec_btd
from repro.kernels.linrec.ref import linrec_ref


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def linrec(a, b, *, impl: str = "auto", bt: int = 256, bd: int = 512):
    """h_t = a_t * h_{t-1} + b_t over axis -2; a, b: (B, T, D)."""
    orig_shape = a.shape
    B = 1
    for s in orig_shape[:-2]:
        B *= s
    a3 = a.reshape(B, orig_shape[-2], orig_shape[-1])
    b3 = b.reshape(B, orig_shape[-2], orig_shape[-1])
    if impl == "ref":
        hs = linrec_ref(a3, b3)
    else:
        hs = linrec_btd(a3, b3, bt=bt, bd=bd, interpret=_use_interpret())
    return hs.reshape(orig_shape)
