"""Pure-jnp oracle for the linrec kernel (lax.scan over time)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def linrec_ref(a, b):
    """a, b: (B, T, D) fp32 -> hs (B, T, D); h_t = a_t h_{t-1} + b_t."""
    af = a.astype(jnp.float32).swapaxes(0, 1)  # (T, B, D)
    bf = b.astype(jnp.float32).swapaxes(0, 1)

    def step(h, ab):
        at, bt_ = ab
        h = at * h + bt_
        return h, h

    h0 = jnp.zeros(af.shape[1:], jnp.float32)
    _, hs = jax.lax.scan(step, h0, (af, bf))
    return hs.swapaxes(0, 1)
