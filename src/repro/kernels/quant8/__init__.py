from repro.kernels.quant8.ops import quantize, dequantize
