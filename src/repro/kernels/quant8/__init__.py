from repro.kernels.quant8.ops import (dequantize, dequantize_rowwise,
                                      quantize, quantize_rowwise)
