"""Pallas TPU kernels: per-block symmetric int8 quantise / dequantise.

Used by the compressed cross-island weight exchange: each BLOCK elements
share one fp32 scale (absmax/127).  Pure HBM-streaming kernels; the win on
TPU is fusing absmax + scale + round + cast into one VMEM pass (XLA emits
two passes: reduce then binary op).

Layout: x reshaped to (nblocks, BLOCK); BLOCK a multiple of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256
ROWS = 8  # quant rows processed per grid step (sublane-friendly)


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)              # (ROWS, BLOCK)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = amax / 127.0
    safe = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / safe), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


def _dequant_kernel(q_ref, s_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)              # (ROWS, 1)
    o_ref[...] = (q * s).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_blocked(xb, *, interpret: bool = False):
    """xb: (nblocks, BLOCK) fp32 -> (int8 same shape, scales (nblocks, 1))."""
    nb, blk = xb.shape
    pad = (-nb) % ROWS
    if pad:
        xb = jnp.pad(xb, ((0, pad), (0, 0)))
    nbp = nb + pad
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(nbp // ROWS,),
        in_specs=[pl.BlockSpec((ROWS, blk), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((ROWS, blk), lambda i: (i, 0)),
                   pl.BlockSpec((ROWS, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nbp, blk), jnp.int8),
                   jax.ShapeDtypeStruct((nbp, 1), jnp.float32)],
        interpret=interpret,
    )(xb)
    return q[:nb], s[:nb]


@functools.partial(jax.jit, static_argnames=("interpret", "out_dtype"))
def dequantize_blocked(q, s, *, out_dtype=jnp.float32,
                       interpret: bool = False):
    nb, blk = q.shape
    pad = (-nb) % ROWS
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0)))
        s = jnp.pad(s, ((0, pad), (0, 0)))
    nbp = nb + pad
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(nbp // ROWS,),
        in_specs=[pl.BlockSpec((ROWS, blk), lambda i: (i, 0)),
                  pl.BlockSpec((ROWS, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROWS, blk), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nbp, blk), out_dtype),
        interpret=interpret,
    )(q, s)
    return out[:nb]
