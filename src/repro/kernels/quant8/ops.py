"""Public quant8 API mirroring core.compression's blockwise layout."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.quant8.kernel import (BLOCK, dequantize_blocked,
                                         quantize_blocked)


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def quantize(x, *, block: int = BLOCK, impl: str = "auto"):
    """x any shape -> (q int8 (nblocks, block), scales (nblocks,))."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    xb = flat.reshape(-1, block)
    if impl == "ref":
        from repro.core.compression import quantize_blockwise
        return quantize_blockwise(x, block=block)
    q, s = quantize_blocked(xb, interpret=_use_interpret())
    return q, s[:, 0]


def dequantize(q, scales, shape, *, out_dtype=jnp.float32,
               impl: str = "auto"):
    if impl == "ref":
        from repro.core.compression import dequantize_blockwise
        return dequantize_blockwise(q, scales, shape)
    flat = dequantize_blocked(q, scales.reshape(-1, 1),
                              out_dtype=out_dtype,
                              interpret=_use_interpret()).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)
