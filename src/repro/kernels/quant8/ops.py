"""Public quant8 API mirroring core.compression's two scale layouts.

* `quantize` / `dequantize` -- BLOCKWISE wire format ((nblocks, block)
  int8 + per-block scales), for serialised transfer.
* `quantize_rowwise` / `dequantize_rowwise` -- per last-dim-channel
  scales; q keeps the input's shape (and therefore its sharding), the
  layout `federated.fl_aggregate_compressed` rides on the TPU hot path.

Both dispatch to the Pallas kernels (interpret mode off-TPU) unless
impl="ref" forces the jnp reference in core.compression.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.quant8.kernel import (BLOCK, dequantize_blocked,
                                         quantize_blocked)

LANES = 128  # TPU lane width: rowwise pads the channel dim up to this


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def quantize(x, *, block: int = BLOCK, impl: str = "auto"):
    """x any shape -> (q int8 (nblocks, block), scales (nblocks,))."""
    if impl == "ref":
        from repro.core.compression import quantize_blockwise
        return quantize_blockwise(x, block=block)
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    xb = flat.reshape(-1, block)
    q, s = quantize_blocked(xb, interpret=_use_interpret())
    return q, s[:, 0]


def dequantize(q, scales, shape, *, out_dtype=jnp.float32,
               impl: str = "auto"):
    if impl == "ref":
        from repro.core.compression import dequantize_blockwise
        return dequantize_blockwise(q, scales, shape)
    flat = dequantize_blocked(q, scales.reshape(-1, 1),
                              out_dtype=out_dtype,
                              interpret=_use_interpret()).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def quantize_rowwise(x, *, impl: str = "auto"):
    """x: (..., C) -> (q int8 SAME shape, fp32 scales (..., 1)).

    The leading dims collapse to kernel rows and C pads up to a lane
    multiple (zero pad never changes a row's absmax), so the result
    matches core.compression.quantize_rowwise exactly while the absmax +
    scale + round + cast run as one fused VMEM pass."""
    if impl == "ref":
        from repro.core.compression import quantize_rowwise as ref
        return ref(x)
    C = x.shape[-1]
    rows = math.prod(x.shape[:-1]) if x.ndim > 1 else 1
    x2 = x.astype(jnp.float32).reshape(rows, C)
    padc = (-C) % LANES
    if padc:
        x2 = jnp.pad(x2, ((0, 0), (0, padc)))
    q, s = quantize_blocked(x2, interpret=_use_interpret())
    return q[:, :C].reshape(x.shape), s.reshape(x.shape[:-1] + (1,))


def dequantize_rowwise(q, scale, *, out_dtype=jnp.float32,
                       impl: str = "auto"):
    """Inverse of quantize_rowwise: q (..., C) int8, scale (..., 1)."""
    if impl == "ref":
        from repro.core.compression import dequantize_rowwise as ref
        return ref(q, scale, out_dtype=out_dtype)
    C = q.shape[-1]
    rows = math.prod(q.shape[:-1]) if q.ndim > 1 else 1
    q2 = q.reshape(rows, C)
    padc = (-C) % LANES
    if padc:
        q2 = jnp.pad(q2, ((0, 0), (0, padc)))
    out = dequantize_blocked(q2, scale.reshape(rows, 1),
                             out_dtype=out_dtype,
                             interpret=_use_interpret())
    return out[:, :C].reshape(q.shape)
