"""Pure-jnp oracle for quant8 (shared with core.compression)."""
from repro.core.compression import (quantize_blockwise as quantize_ref,
                                    dequantize_blockwise as dequantize_ref,
                                    quantize_rowwise as quantize_rowwise_ref,
                                    dequantize_rowwise as
                                    dequantize_rowwise_ref)
