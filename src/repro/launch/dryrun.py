import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Per cell this AOT-compiles (no device allocation beyond host placeholders):
  train_4k              -> the FL island train step (vmapped over pods on the
                           multi-pod mesh) AND the fl_aggregate exchange
  prefill_32k           -> serve prefill step
  decode_32k / long_500k-> serve decode step (KV/state cache as inputs)
and records memory_analysis / cost_analysis / per-collective traffic into
artifacts/dryrun/<arch>__<shape>__<mesh>.json for the roofline tables.

Serve cells (prefill/decode) AOT-compile every candidate weight layout
(stationary / hybrid / fsdp, see dist/sharding.SERVE_LAYOUTS) under the
config's own cache spec and let repro.dist.policy pick one from the XLA
memory_analysis numbers with headroom-aware scoring.  When NO baseline
candidate fits, the policy walks the analytic (weight layout x cache
spec) product frontier (ring-sharded / int8 caches, chunked prefill; see
models/cache.py) best-first, compiling candidates until one is
XLA-verified under budget -- so the chosen candidate is always backed by
a real memory_analysis, never an analytic estimate.  The decision
(chosen layout + cache spec, per-candidate peak HBM, headroom, reason)
lands in the artifact under "layout_decision"; cache-carrying entries
also record cache_bytes_analytic next to an XLA-derived counterpart for
the calibration pin in tests/test_cache_spec.py.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x22b --shape train_4k --mesh multi
  python -m repro.launch.dryrun --all [--mesh both] [--force]
  python -m repro.launch.dryrun --check-fit --mesh both   # analytic CI gate
"""

import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / \
    os.environ.get("REPRO_DRYRUN_DIR", "dryrun")


def _cell_path(arch: str, shape: str, mesh: str) -> Path:
    return ARTIFACTS / f"{arch}__{shape}__{mesh}.json"


# ---------------------------------------------------------------------------
# In-process lowering of one cell
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, mesh_kind: str,
             overrides: dict | None = None) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.core import federated
    from repro.dist import hlo_analysis as H
    from repro.dist import hlo_cost
    from repro.dist import policy as dist_policy
    from repro.dist.sharding import (DEFAULT_RULES, ISLAND_RULES,
                                     serve_layout_rules, spec_tree_for,
                                     use_rules)
    from repro.launch import steps as S
    from repro.launch.mesh import make_production_mesh, n_islands
    from repro.models import build_model
    from repro.models.config import SHAPES
    from repro.models.param import ParamDef, abstract_params, is_def, pdef
    from repro.optim import adamw, opt_state_defs

    overrides = dict(overrides or {})
    # layout override for sweeps: "auto" (policy decides), or a layout
    # name from sharding.SERVE_LAYOUTS.  Legacy `_serve_rules: False`
    # means "force the FSDP training layout".
    forced_layout = overrides.pop("_layout", None)
    if forced_layout == "auto":
        forced_layout = None          # explicit "auto" = policy decides
    if not overrides.pop("_serve_rules", True):
        forced_layout = forced_layout or "fsdp"
    cfg = get_config(arch)
    if overrides:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, **overrides)
    model = build_model(cfg)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    P = n_islands(mesh)

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "mesh_shape": dict(mesh.shape), "status": "ok",
        "n_params": model.n_params, "n_active_params": model.n_active_params,
        "overrides": overrides or {},
        "entries": {},
    }

    if shape_name == "long_500k" and not cfg.sub_quadratic:
        result["status"] = "skipped"
        result["reason"] = ("full quadratic attention at 524288 tokens; "
                            "long-context runs only for ssm/hybrid/windowed "
                            "archs (DESIGN.md SS6)")
        return result

    def specs(defs, rules):
        return spec_tree_for(defs, mesh, rules)

    def lower_entry(name, fn, in_shardings, args, donate=(), rules=DEFAULT_RULES):
        t0 = time.time()
        jitted = jax.jit(fn, in_shardings=in_shardings,
                         donate_argnums=donate)
        with use_rules(rules), mesh:  # ambient mesh so constrain() resolves
            lowered = jitted.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        flops_xla, byts_xla = H.cost_analysis_terms(compiled)
        txt = compiled.as_text()
        # PRIMARY: trip-count-aware HLO cost model (XLA's cost_analysis
        # counts scan bodies once; see dist/hlo_cost.py + EXPERIMENTS.md).
        hc = hlo_cost.analyze(txt)
        roof = H.Roofline(hc["flops"], hc["hbm_bytes"],
                          hc["collective_bytes"])
        entry = {
            "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
            "hlo_cost": {k: hc[k] for k in
                         ("flops", "hbm_bytes", "collective_bytes",
                          "collective_by_op", "transcendentals")},
            "hlo_cost_diagnostics": hc["diagnostics"][:20],
            "xla_cost_analysis_once": {"flops": flops_xla,
                                       "bytes_accessed": byts_xla},
            "collectives_once": H.collective_bytes(txt),
            "memory_analysis": H.memory_analysis_dict(compiled),
            "roofline": roof.as_dict(),
            "hlo_lines": txt.count("\n"),
        }
        result["entries"][name] = entry
        return entry

    if shape.kind == "train":
        p_defs = model.param_defs()
        o_defs = opt_state_defs(p_defs)
        in_defs = model.input_defs(shape)
        opt = adamw(1e-4)
        if P > 1:
            from repro.models.param import stack_defs as _stack

            def island_stack(defs):
                return jax.tree.map(
                    lambda d: ParamDef((P,) + d.shape, d.dtype,
                                       ("island",) + d.logical_axes,
                                       d.init, d.fan_in_axes),
                    defs, is_leaf=is_def)

            p_defs = island_stack(p_defs)
            o_defs = island_stack(o_defs)
            in_defs = jax.tree.map(
                lambda d: ParamDef((P, d.shape[0] // P) + d.shape[1:],
                                   d.dtype, ("island",) + d.logical_axes,
                                   d.init, d.fan_in_axes),
                in_defs, is_leaf=is_def)
        step = S.make_fl_train_step(model, opt, P)
        args = (abstract_params(p_defs), abstract_params(o_defs),
                abstract_params(in_defs))
        shardings = (specs(p_defs, ISLAND_RULES), specs(o_defs, ISLAND_RULES),
                     specs(in_defs, ISLAND_RULES))
        lower_entry("train_step", step, shardings, args, donate=(0, 1),
                    rules=ISLAND_RULES)

        if P > 1:
            agg = S.make_fl_aggregate()
            mix_def = pdef((P, P), (None, None), dtype=jnp.float32)
            agg_args = (abstract_params(p_defs),
                        jax.ShapeDtypeStruct((P, P), jnp.float32))
            agg_shard = (specs(p_defs, DEFAULT_RULES),
                         specs({"m": mix_def}, DEFAULT_RULES)["m"])
            lower_entry("fl_aggregate", agg, agg_shard, agg_args, donate=(0,))
            # beyond-paper: int8-delta compressed exchange (wire = q8+scales)
            aggc = S.make_fl_aggregate(compress=True)
            aggc_args = (abstract_params(p_defs), abstract_params(p_defs),
                         jax.ShapeDtypeStruct((P, P), jnp.float32))
            aggc_shard = (agg_shard[0], agg_shard[0], agg_shard[1])
            lower_entry("fl_aggregate_q8", aggc, aggc_shard, aggc_args,
                        donate=(0,))
            # analytic bytes-on-wire per exchange round (whole stacked
            # tree, all islands).  The q8 wire counts follow
            # compression.compressed_bytes: blockwise includes the
            # block-multiple PAD the wire actually carries; rowwise is
            # the sharding-preserving layout fl_aggregate_q8 ships.
            from repro.core import compression as _comp
            sds = abstract_params(p_defs)
            result["entries"]["fl_aggregate"]["wire_bytes_analytic"] = {
                "raw_storage": _comp.compressed_bytes(sds, mode="none")}
            result["entries"]["fl_aggregate_q8"]["wire_bytes_analytic"] = {
                "q8_rowwise": _comp.compressed_bytes(sds, mode="q8_rowwise"),
                "q8_wire_blockwise": _comp.compressed_bytes(sds, mode="q8"),
                "q8_topk_wire": _comp.compressed_bytes(sds, mode="q8_topk"),
            }
        else:
            result["entries"]["fl_aggregate"] = {
                "note": "single island on the single-pod mesh: the exchange "
                        "is an identity; lowered on the multi-pod mesh"}

    else:  # prefill / decode: (weight layout x cache spec) product,
        #       picked by repro.dist.policy from XLA memory_analysis
        import dataclasses as _dc
        from repro.dist.sharding import SERVE_LAYOUTS
        p_defs = model.param_defs()
        in_defs = model.input_defs(shape)
        B, Sq = shape.global_batch, shape.seq_len
        base = "prefill_step" if shape.kind == "prefill" else "decode_step"
        spec_capable = (model.supports_cache_spec
                        and model._cache_defs is not None)

        def _sharded(defs, rules):
            return dist_policy.sharded_bytes(defs, mesh, rules)

        def probe(layout, cache_spec=None, chunked=False):
            """AOT-compile the step under one (layout, cache spec
            [, chunked]) candidate; the policy scores the XLA
            memory_analysis + hlo_cost roofline.  cache_spec=None keeps
            the config's own spec (the baseline probes)."""
            rules = serve_layout_rules(layout)
            m = model if not cache_spec else \
                build_model(_dc.replace(cfg, cache_spec=cache_spec))
            if chunked:
                C = dist_policy.CHUNK_TOKENS
                step = S.make_chunk_prefill_step(m)
                ch_in = {
                    "tokens": pdef((B, C), ("batch", None), dtype=jnp.int32),
                    "positions": pdef((B, C), ("batch", None),
                                      dtype=jnp.int32),
                    "last_index": pdef((B,), ("batch",), dtype=jnp.int32),
                }
                c_defs = m.cache_defs(B, Sq)
                all_defs, donate = (p_defs, ch_in, c_defs), (2,)
            elif shape.kind == "prefill":
                step = S.make_prefill_step(m)
                all_defs, donate = (p_defs, in_defs), ()
            else:
                c_defs = m.cache_defs(B, Sq)
                step = S.make_decode_step(m)
                all_defs, donate = (p_defs, in_defs, c_defs), (2,)
            args = tuple(abstract_params(d) for d in all_defs)
            ev_key = layout + (f"+{cache_spec}" if cache_spec else "") + \
                ("+chunked" if chunked else "")
            entry = lower_entry(f"{base}@{ev_key}", step,
                                tuple(specs(d, rules) for d in all_defs),
                                args, donate=donate, rules=rules)
            # analytic cache bytes + an XLA-derived counterpart for the
            # 2x calibration pin (tests/test_cache_spec.py): decode
            # carries the cache as a donated ARGUMENT, one-shot prefill
            # RETURNS it as a non-aliased output.
            ma = entry["memory_analysis"]
            if len(all_defs) == 3:
                entry["cache_bytes_analytic"] = _sharded(all_defs[2], rules)
                entry["cache_bytes_xla_derived"] = max(
                    ma.get("argument_size_in_bytes", 0)
                    - _sharded(p_defs, rules) - _sharded(all_defs[1], rules),
                    0.0)
            elif spec_capable:
                entry["cache_bytes_analytic"] = \
                    _sharded(m.cache_defs(B, Sq), rules)
                entry["cache_bytes_xla_derived"] = max(
                    ma.get("output_size_in_bytes", 0)
                    - ma.get("alias_size_in_bytes", 0), 0.0)
            return dist_policy.eval_from_compiled(
                layout, ma, entry["roofline"],
                cache=cache_spec or "", chunked=chunked)

        if forced_layout:
            probe(forced_layout)
            result["entries"][base] = \
                result["entries"].pop(f"{base}@{forced_layout}")
            result["layout_decision"] = {"layout": forced_layout,
                                         "reason": "forced by override"}
        else:
            # baseline probes: the 3 weight layouts under the config's own
            # cache spec (today's table when everything fits)
            evals = [probe(layout) for layout in SERVE_LAYOUTS]
            decision = dist_policy.decide(evals)
            if not decision.fits and spec_capable:
                # walk the analytic (layout x cache spec) frontier,
                # compiling candidates best-first until one is
                # XLA-verified to fit (bounded tries); skip the head/bf16
                # one-shot candidates -- that IS the baseline convention
                # already compiled above.
                cap = decision.budget_bytes * decision.margin
                cands = [
                    (lo, cs, ch) for (lo, cs, ch)
                    in dist_policy.serve_product_candidates(model, shape)
                    if cs is not None
                    and not (cs == "head/bf16" and not ch
                             and cfg.cache_spec in ("auto", "head/bf16"))]
                scored = sorted(
                    ((dist_policy.analytic_eval(
                        model, shape, mesh, lo, cache_spec=cs, chunked=ch),
                      lo, cs, ch) for lo, cs, ch in cands),
                    key=lambda t: (t[0].hbm_bytes > cap, t[0].step_time_s))
                for _, lo, cs, ch in scored[:6]:
                    ev = probe(lo, cache_spec=cs, chunked=ch)
                    evals.append(ev)
                    if ev.hbm_bytes <= cap:
                        break
                decision = dist_policy.decide(evals)
            result["layout_decision"] = decision.as_dict()
            # canonical entry = the chosen probe; losing probes stay only
            # as compact evals inside layout_decision["candidates"]
            result["entries"][base] = \
                result["entries"].pop(f"{base}@{decision.key}")
            for k in [k for k in result["entries"]
                      if k.startswith(base + "@")]:
                del result["entries"][k]

    return result


# ---------------------------------------------------------------------------
# Driver: one subprocess per cell (isolates the 512-device env + memory)
# ---------------------------------------------------------------------------

def all_cells(meshes=("single", "multi")) -> list[tuple[str, str, str]]:
    from repro.configs import list_archs
    from repro.models.config import SHAPES
    cells = []
    for arch in list_archs():
        for shape in SHAPES:
            for mesh in meshes:
                cells.append((arch, shape, mesh))
    return cells


def check_fit(meshes=("single", "multi")) -> int:
    """Analytic-only CI gate: every serve cell must have >=1 fitting
    (weight layout x cache spec) product.  Runs on AbstractMesh (no
    512-device env, no compiles) so the CI scale job can assert coverage
    in seconds; the compiled sweep is the ground truth behind it."""
    from repro.configs import get_config, list_archs
    from repro.dist import policy as dist_policy
    from repro.launch.mesh import abstract_production_mesh
    from repro.models import build_model
    from repro.models.config import SHAPES
    bad = []
    for mesh_kind in meshes:
        mesh = abstract_production_mesh(multi_pod=(mesh_kind == "multi"))
        for arch in list_archs():
            cfg = get_config(arch)
            if cfg.family == "cnn":
                continue
            model = build_model(cfg)
            for shape_name, shape in SHAPES.items():
                if shape.kind == "train":
                    continue
                if shape_name == "long_500k" and not cfg.sub_quadratic:
                    continue
                d = dist_policy.analytic_serve_decision(model, shape, mesh)
                print(f"[check-fit] {mesh_kind:6s} {arch:22s} "
                      f"{shape_name:12s} {d.key:30s} "
                      f"peak={d.chosen.hbm_bytes/1e9:7.2f} GB "
                      f"{'ok' if d.fits else 'NO-FIT'}", flush=True)
                if not d.fits:
                    bad.append((arch, shape_name, mesh_kind))
    if bad:
        print(f"[check-fit] {len(bad)} cells with NO fitting "
              f"(layout, cache) product: {bad}", flush=True)
        return 1
    print("[check-fit] every serve cell has >=1 fitting (weight, cache) "
          "layout", flush=True)
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--check-fit", action="store_true",
                    help="analytic-only: assert every serve cell has >=1 "
                         "fitting (weight layout x cache spec) product")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--overrides", default=None,
                    help="JSON dict of ModelConfig overrides (perf sweeps)")
    ap.add_argument("--tag", default=None,
                    help="artifact filename suffix for override sweeps")
    args = ap.parse_args()
    ARTIFACTS.mkdir(parents=True, exist_ok=True)

    if args.check_fit:
        meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
        sys.exit(check_fit(meshes))

    if args.all:
        meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
        cells = all_cells(meshes)
        todo = [c for c in cells if args.force or not _cell_path(*c).exists()]
        print(f"[dryrun] {len(todo)}/{len(cells)} cells to run", flush=True)
        failures = []
        for i, (arch, shape, mesh) in enumerate(todo):
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch",
                   arch, "--shape", shape, "--mesh", mesh]
            print(f"[dryrun {i+1}/{len(todo)}] {arch} {shape} {mesh}",
                  flush=True)
            r = subprocess.run(cmd, capture_output=True, text=True)
            if r.returncode != 0:
                failures.append((arch, shape, mesh))
                print(r.stdout[-2000:], r.stderr[-2000:], flush=True)
        print(f"[dryrun] done; {len(failures)} failures: {failures}",
              flush=True)
        sys.exit(1 if failures else 0)

    assert args.arch and args.shape
    overrides = json.loads(args.overrides) if args.overrides else None
    try:
        res = run_cell(args.arch, args.shape, args.mesh, overrides)
    except Exception:
        res = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "status": "error", "traceback": traceback.format_exc()}
    name = f"{args.arch}__{args.shape}__{args.mesh}"
    if args.tag:
        name += f"__{args.tag}"
    out = ARTIFACTS / f"{name}.json"
    out.write_text(json.dumps(res, indent=2, default=str))
    print(json.dumps({k: v for k, v in res.items() if k != "entries"},
                     indent=2, default=str))
    if "layout_decision" in res:
        d = res["layout_decision"]
        cs = d.get("cache_spec", "")
        print(f"  layout={d['layout']}"
              + (f" cache={cs}" if cs else "")
              + (" chunked" if d.get("chunked") else "")
              + f" ({d.get('reason', '')})")
    for ename, e in res.get("entries", {}).items():
        if "roofline" in e:
            r = e["roofline"]
            print(f"  {ename}: dominant={r['dominant']} "
                  f"t_comp={r['t_compute_s']:.2e}s "
                  f"t_mem={r['t_memory_s']:.2e}s "
                  f"t_coll={r['t_collective_s']:.2e}s "
                  f"(lower {e['lower_s']}s compile {e['compile_s']}s)")
    if res["status"] == "error":
        print(res["traceback"][-3000:])
        sys.exit(1)


if __name__ == "__main__":
    main()
