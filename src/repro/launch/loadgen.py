"""Seeded open-loop load generator + trace driver for the serve loops.

OPEN-LOOP: arrivals are drawn once from a Poisson process at a target
QPS and never react to the server (no closed-loop back-pressure), so a
slow server shows up as queueing delay in the latency percentiles
instead of silently throttling offered load.  Prompt lengths are
lognormal (most requests short, a heavy tail), output lengths geometric,
and a configurable fraction of requests draw one of ``n_prefixes``
common prompt prefixes -- the workload shape that makes block-table
prefix sharing (core/paging.py) pay off.

Everything is a pure function of ``LoadConfig``: two ``generate()``
calls with the same seed produce identical arrival times, prompts and
output budgets, and ``run_trace(..., tick_s=...)`` drives a loop on a
deterministic VIRTUAL clock (SimRecord-style, like core/scenarios.py)
so a whole load test replays bit-identically.  Pass ``tick_s=None`` for
the wall-clock mode the latency benchmark uses
(benchmarks/serve_load.py -> BENCH_serve.json).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np


@dataclasses.dataclass(frozen=True)
class LoadConfig:
    qps: float = 8.0
    duration_s: float = 4.0          # arrival horizon (open loop)
    seed: int = 0
    vocab_size: int = 499
    prompt_mean: int = 24            # lognormal median, clipped to bounds
    prompt_sigma: float = 0.6
    prompt_min: int = 4
    prompt_max: int = 96
    out_mean: int = 8                # geometric mean, clipped to bounds
    out_min: int = 2
    out_max: int = 32
    shared_prefix_frac: float = 0.0  # fraction drawing a common prefix
    shared_prefix_len: int = 16
    n_prefixes: int = 2


@dataclasses.dataclass(frozen=True)
class Arrival:
    rid: int
    t: float                         # seconds since trace start
    prompt: np.ndarray               # (T,) int32
    max_new: int


@dataclasses.dataclass(frozen=True)
class ServedRecord:
    rid: int
    t_arrive: float
    t_first: float                   # first output token visible
    t_done: float
    n_prompt: int
    out: tuple                       # generated token ids

    @property
    def latency(self) -> float:
        return self.t_done - self.t_arrive

    @property
    def ttft(self) -> float:
        return self.t_first - self.t_arrive


def generate(cfg: LoadConfig) -> list[Arrival]:
    """Draw the full open-loop trace; deterministic in cfg (incl. seed)."""
    rng = np.random.default_rng(cfg.seed)
    prefixes = [rng.integers(0, cfg.vocab_size, cfg.shared_prefix_len)
                .astype(np.int32) for _ in range(cfg.n_prefixes)]
    arrivals = []
    t = 0.0
    rid = 0
    while True:
        t += rng.exponential(1.0 / cfg.qps)
        if t >= cfg.duration_s:
            break
        n = int(np.clip(round(np.exp(rng.normal(np.log(cfg.prompt_mean),
                                                cfg.prompt_sigma))),
                        cfg.prompt_min, cfg.prompt_max))
        prompt = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
        if cfg.shared_prefix_frac and rng.random() < cfg.shared_prefix_frac:
            pre = prefixes[int(rng.integers(cfg.n_prefixes))]
            tail = max(n - len(pre), 1)
            prompt = np.concatenate([pre, prompt[:tail]])
        m = int(np.clip(rng.geometric(1.0 / cfg.out_mean),
                        cfg.out_min, cfg.out_max))
        arrivals.append(Arrival(rid=rid, t=float(t), prompt=prompt,
                                max_new=m))
        rid += 1
    return arrivals


def run_trace(loop, arrivals: list[Arrival], *, tick_s: float | None = None,
              max_ticks: int = 100_000) -> list[ServedRecord]:
    """Drive a serve loop through an arrival trace.

    tick_s=None  -> WALL clock: request timestamps come from
                    time.monotonic(); this is what the benchmark measures.
    tick_s=float -> VIRTUAL clock: every tick advances exactly tick_s
                    seconds, making the whole run (timestamps included) a
                    deterministic function of (loop params, trace).
    """
    from repro.launch.serve_loop import Request

    pending = sorted(arrivals, key=lambda a: a.t)
    reqs: dict[int, Request] = {}
    arrive_t = {a.rid: a.t for a in arrivals}
    first_t: dict[int, float] = {}
    records: list[ServedRecord] = []
    t0 = time.monotonic()
    tick = 0
    while len(records) < len(arrivals):
        assert tick < max_ticks, "trace did not drain"
        now = tick * tick_s if tick_s is not None else time.monotonic() - t0
        while pending and pending[0].t <= now:
            a = pending.pop(0)
            reqs[a.rid] = Request(rid=a.rid, prompt=a.prompt,
                                  max_new=a.max_new)
            loop.submit(reqs[a.rid])
        if not loop.queue and not loop.live and pending:
            # idle until the next arrival
            if tick_s is None:
                time.sleep(min(pending[0].t - now, 0.01))
            tick += 1
            continue
        finished = loop.tick()
        tick += 1
        end = tick * tick_s if tick_s is not None else time.monotonic() - t0
        for rid, r in reqs.items():
            if rid not in first_t and r.out:
                first_t[rid] = end
        for r in finished:
            records.append(ServedRecord(
                rid=r.rid, t_arrive=arrive_t[r.rid],
                t_first=first_t[r.rid], t_done=end,
                n_prompt=len(r.prompt), out=tuple(r.out)))
    return sorted(records, key=lambda r: r.rid)


def summarize(records: list[ServedRecord], wall_s: float) -> dict:
    """p50/p99 request latency + time-to-first-token and tokens/s."""
    lat = np.array([r.latency for r in records])
    ttft = np.array([r.ttft for r in records])
    n_tokens = int(sum(len(r.out) for r in records))
    return {
        "n_requests": len(records),
        "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 2),
        "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 2),
        "ttft_p50_ms": round(float(np.percentile(ttft, 50)) * 1e3, 2),
        "ttft_p99_ms": round(float(np.percentile(ttft, 99)) * 1e3, 2),
        "tokens_out": n_tokens,
        "tokens_per_s": round(n_tokens / max(wall_s, 1e-9), 2),
        "wall_s": round(wall_s, 3),
    }
