"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before the first jax init).

  single-pod : (data=16, model=16)            = 256 chips (one v5e pod slice)
  multi-pod  : (pod=2, data=16, model=16)     = 512 chips; `pod` is the FL
               island axis (1 island per pod, paper semantics).
"""
from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh():
    """Whatever this process actually has (CPU smoke tests: 1 device)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"), axis_types=_auto(2))


def n_islands(mesh) -> int:
    return mesh.shape.get("pod", 1)
