"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before the first jax init).

  single-pod : (data=16, model=16)            = 256 chips (one v5e pod slice)
  multi-pod  : (pod=2, data=16, model=16)     = 512 chips; `pod` is the FL
               island axis (1 island per pod, paper semantics).
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types across jax versions (axis_types=
    only exists on newer jax, where Auto is the default anyway)."""
    at = getattr(jax.sharding, "AxisType", None)
    if at is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(at.Auto,) * len(axes))
        except TypeError:
            pass
    return jax.make_mesh(shape, axes)


def production_mesh_spec(*, multi_pod: bool = False):
    """(shape, axes) of the production mesh, without touching devices."""
    if multi_pod:
        return (2, 16, 16), ("pod", "data", "model")
    return (16, 16), ("data", "model")


def make_production_mesh(*, multi_pod: bool = False):
    shape, axes = production_mesh_spec(multi_pod=multi_pod)
    return make_mesh(shape, axes)


def abstract_production_mesh(*, multi_pod: bool = False):
    """AbstractMesh with the production shape: usable for ANALYTIC layout
    checks (only mesh.shape is consulted) without the 512-device env."""
    from repro.dist.sharding import abstract_mesh
    shape, axes = production_mesh_spec(multi_pod=multi_pod)
    return abstract_mesh(shape, axes)


def make_host_mesh():
    """Whatever this process actually has (CPU smoke tests: 1 device)."""
    n = len(jax.devices())
    return make_mesh((1, n), ("data", "model"))


def n_islands(mesh) -> int:
    return mesh.shape.get("pod", 1)
