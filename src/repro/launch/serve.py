"""Batched serving launcher: prefill a batch of prompts, decode greedily.

  PYTHONPATH=src python -m repro.launch.serve --arch falcon-mamba-7b \
      --smoke --batch 4 --prompt-len 64 --gen 32

Weight layout (stationary / hybrid / fsdp) is chosen by the memory-aware
policy in repro.dist.policy (`--layout auto`, the default), or forced
with `--layout <name>`.  The chosen RuleSet is ambient while the steps
trace, so `constrain()` calls in model code resolve against it; at smoke
scale (1 host device) every layout degenerates to replicated and the
decision is only reported.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.dist import policy as dist_policy
from repro.dist.sharding import SERVE_LAYOUTS, use_rules
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import build_model
from repro.models.config import ShapeConfig


def pick_layout(model, mesh, *, batch: int, seq_len: int,
                layout: str = "auto", cache: str = "auto"):
    """Resolve the serve (weight layout, cache spec): the policy's
    analytic product decision for "auto"/"auto", else the named layout
    and/or CacheSpec (the full candidate table is still computed so the
    caller can log headroom)."""
    import dataclasses
    shape = ShapeConfig("serve", "decode", seq_len, batch)
    decision = dist_policy.analytic_serve_decision(model, shape, mesh)
    if cache != "auto" and model.supports_cache_spec:
        from repro.models.cache import CacheSpec
        cache = CacheSpec.parse(cache).name
    if layout == "auto" and cache == "auto":
        return decision
    cands = [e for e in decision.evals
             if (layout == "auto" or e.layout == layout)
             and (cache == "auto" or e.cache == cache)
             and not e.chunked]
    if not cands:
        # a spec outside the candidate table (e.g. "ring:2/int8"):
        # evaluate the forced combination directly
        cands = [dist_policy.analytic_eval(
            model, shape, mesh,
            layout if layout != "auto" else decision.layout,
            cache_spec=None if cache == "auto" else cache)]
    cap = decision.budget_bytes * decision.margin
    fits = [e for e in cands if e.hbm_bytes <= cap]
    best = min(fits or cands, key=lambda e: e.step_time_s)
    if best.key != decision.key:
        decision = dataclasses.replace(
            decision, layout=best.layout, cache_spec=best.cache,
            chunked=best.chunked, fits=bool(fits),
            evals=decision.evals + tuple(
                e for e in cands if e not in decision.evals),
            reason=f"forced layout={layout} cache={cache} (policy "
                   f"preferred {decision.key}: {decision.reason})")
    return decision


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="falcon-mamba-7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--layout", default="auto",
                    choices=["auto"] + sorted(SERVE_LAYOUTS))
    ap.add_argument("--cache", default="auto",
                    help="KV-cache spec 'layout[:shards]/dtype' (e.g. "
                         "ring:4/int8, head/bf16); 'auto' lets the "
                         "policy pick (models/cache.py)")
    ap.add_argument("--paged", action="store_true",
                    help="serve through the block-table paged "
                         "continuous-batching loop (PagedServeLoop) "
                         "instead of the fixed-batch prefill+decode path")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="KV block pool size (default: sized so the pool "
                         "covers batch x (prompt+gen))")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    mesh = make_host_mesh()
    decision = pick_layout(model, mesh, batch=args.batch,
                           seq_len=args.prompt_len + args.gen,
                           layout=args.layout, cache=args.cache)
    if (model.supports_cache_spec and decision.cache_spec
            and decision.cache_spec != cfg.cache_spec):
        import dataclasses as _dc
        # params are spec-independent: only the cache tree changes shape
        cfg = _dc.replace(cfg, cache_spec=decision.cache_spec)
        model = build_model(cfg)
    print(f"[serve] layout={decision.layout}"
          + (f" cache={decision.cache_spec}" if decision.cache_spec else "")
          + f" (peak {decision.chosen.hbm_bytes/1e9:.2f} GB/dev, "
          f"headroom {decision.headroom_bytes()/1e9:.2f} GB) "
          f"-- {decision.reason}")
    if args.paged:
        from repro.launch.serve_loop import PagedServeLoop, Request
        rng = np.random.default_rng(args.seed)
        B, T = args.batch, args.prompt_len
        per_seq = T + args.gen
        nb = args.num_blocks or -(-(B * per_seq + args.block_size)
                                  // args.block_size)
        loop = PagedServeLoop(model, params, max_batch=B, num_blocks=nb,
                              block_size=args.block_size,
                              chunk=max(args.block_size * 4, 32),
                              layout=decision.layout)
        for i in range(2 * B):   # oversubscribe: requests join mid-flight
            loop.submit(Request(
                rid=i, prompt=rng.integers(0, cfg.vocab_size, T)
                .astype(np.int32), max_new=args.gen))
        t0 = time.time()
        done = loop.run_until_drained()
        wall = time.time() - t0
        toks = sum(len(r.out) for r in done)
        print(f"[serve] paged loop: {len(done)} reqs, {toks} tokens in "
              f"{wall*1e3:.1f}ms ({toks/max(wall,1e-9):.0f} tok/s); "
              f"pool {nb}x{args.block_size}, "
              f"shared {loop.alloc.stats['shared_blocks']} blocks, "
              f"{loop.preemptions} preemptions")
        print(f"[serve] sample generations (first 12 ids): "
              f"{[r.out[:12] for r in done[:4]]}")
        return

    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model))

    rng = np.random.default_rng(args.seed)
    B, T = args.batch, args.prompt_len
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)}
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_len, cfg.d_model)), jnp.bfloat16)
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, T, cfg.d_model)), jnp.bfloat16)

    # rules AND mesh must be ambient while the steps TRACE (first call):
    # constrain() in model code no-ops without an ambient mesh, so the
    # chosen layout only binds under both contexts
    with use_rules(decision.rules), mesh:
        t0 = time.time()
        nxt, cache = prefill(params, batch)
        jax.block_until_ready(nxt)
        t_prefill = time.time() - t0
        print(f"[serve] prefill {B}x{T}: {t_prefill*1e3:.1f}ms "
              f"({B*T/t_prefill:.0f} tok/s)")

        out = [np.asarray(nxt)]
        t0 = time.time()
        for i in range(args.gen - 1):
            nxt, cache = decode(params, {
                "tokens": nxt[:, None].astype(jnp.int32),
                "positions": jnp.full((B, 1), T + i, jnp.int32)}, cache)
            out.append(np.asarray(nxt))
        jax.block_until_ready(nxt)
        t_dec = time.time() - t0
    toks = np.stack(out, axis=1)
    print(f"[serve] decode {args.gen} steps: {t_dec*1e3:.1f}ms "
          f"({B*(args.gen-1)/max(t_dec,1e-9):.0f} tok/s)")
    print(f"[serve] sample generations (first 12 ids): {toks[:, :12].tolist()}")


if __name__ == "__main__":
    main()
