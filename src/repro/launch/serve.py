"""Batched serving launcher: prefill a batch of prompts, decode greedily.

  PYTHONPATH=src python -m repro.launch.serve --arch falcon-mamba-7b \
      --smoke --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import build_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="falcon-mamba-7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model))

    rng = np.random.default_rng(args.seed)
    B, T = args.batch, args.prompt_len
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)}
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_len, cfg.d_model)), jnp.bfloat16)
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, T, cfg.d_model)), jnp.bfloat16)

    t0 = time.time()
    nxt, cache = prefill(params, batch)
    jax.block_until_ready(nxt)
    t_prefill = time.time() - t0
    print(f"[serve] prefill {B}x{T}: {t_prefill*1e3:.1f}ms "
          f"({B*T/t_prefill:.0f} tok/s)")

    out = [np.asarray(nxt)]
    t0 = time.time()
    for i in range(args.gen - 1):
        nxt, cache = decode(params, {
            "tokens": nxt[:, None].astype(jnp.int32),
            "positions": jnp.full((B, 1), T + i, jnp.int32)}, cache)
        out.append(np.asarray(nxt))
    jax.block_until_ready(nxt)
    t_dec = time.time() - t0
    toks = np.stack(out, axis=1)
    print(f"[serve] decode {args.gen} steps: {t_dec*1e3:.1f}ms "
          f"({B*(args.gen-1)/max(t_dec,1e-9):.0f} tok/s)")
    print(f"[serve] sample generations (first 12 ids): {toks[:, :12].tolist()}")


if __name__ == "__main__":
    main()
