"""Continuous-batching serve loops (vLLM-flavoured, beyond-paper).

Two cache disciplines behind one Request/submit/tick API:

* ``ServeLoop`` -- the original CONTIGUOUS cache: a fixed pool of B slots
  shares one batched KV/state cache sized B x max_len; requests join
  mid-flight (prefill into a free slot), a single batched decode step
  runs for ALL live slots each tick with PER-SLOT positions, and
  finished slots are recycled.  Works for every family with a decode
  cache (incl. SSM state), but concurrency is capped at max_batch and a
  short request pays for max_len positions of HBM.

* ``PagedServeLoop`` -- the BLOCK-TABLE PAGED cache (transformer
  families): one KV block pool shared by all slots (core/paging.py
  allocator: free list, refcounts, prefix sharing), per-slot block
  tables mapping position -> (block, offset), chunked+bucketed prefill
  so any prompt length streams through a bounded number of jit cache
  entries, lazy block growth during decode, and preemption (requeue the
  youngest sequence) when the pool runs dry.  Greedy decode is
  token-identical to ServeLoop (tests/test_serve_loop.py).

CPU-runnable at smoke scale; the same loops drive TPU serving, with the
weight layout (stationary / hybrid / fsdp) picked per model by the
memory-aware policy in repro.dist.policy -- pass `mesh=` to get an
analytic decision, or `layout=` to force one.
"""
from __future__ import annotations

import contextlib
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.paging import BlockAllocator, OutOfBlocks


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (T,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class _ServeBase:
    """Layout/mesh plumbing + queue discipline shared by both loops."""

    def __init__(self, model, params, *, max_batch: int, mesh=None,
                 layout: str = "auto", shape=None):
        self.model = model
        self.params = params
        self.B = max_batch
        self.layout_decision = None
        self.rules = None
        self.mesh = mesh
        if layout != "auto":
            from repro.dist.sharding import serve_layout_rules
            self.rules = serve_layout_rules(layout)
        elif mesh is not None:
            from repro.dist import policy as dist_policy
            self.layout_decision = dist_policy.analytic_serve_decision(
                model, shape, mesh)
            self.rules = self.layout_decision.rules
        self.live: dict[int, Request] = {}   # slot -> request
        self.free = list(range(max_batch))
        self.queue: list[Request] = []
        # host-side truth for per-slot positions.  int32, NOT int64: the
        # device `_next`/positions arrays are int32, and an int64 host
        # array silently wraps on the implicit cast once lengths cross
        # 2^31 (regression-pinned in tests/test_serve_loop.py).
        self.lengths = np.zeros(max_batch, np.int32)
        self._next = jnp.zeros((max_batch,), jnp.int32)

    def _rules_ctx(self):
        """Make the chosen layout's rules AND the mesh ambient while a
        step traces: constrain() in model code no-ops without an ambient
        mesh, so the layout only binds under both (plain nullcontext for
        CPU smoke tests with neither)."""
        stack = contextlib.ExitStack()
        if self.rules is not None:
            from repro.dist.sharding import use_rules
            stack.enter_context(use_rules(self.rules))
        if self.mesh is not None:
            stack.enter_context(self.mesh)
        return stack

    def submit(self, req: Request):
        self.queue.append(req)

    def run_until_drained(self, max_ticks: int = 10_000):
        done = []
        for _ in range(max_ticks):
            done += self.tick()
            if not self.live and not self.queue:
                break
        return done


class ServeLoop(_ServeBase):
    """Contiguous per-slot cache (see module docstring)."""

    def __init__(self, model, params, *, max_batch: int = 4,
                 max_len: int = 512, mesh=None, layout: str = "auto",
                 cache_spec: str | None = None):
        from repro.models.config import ShapeConfig
        super().__init__(model, params, max_batch=max_batch, mesh=mesh,
                         layout=layout,
                         shape=ShapeConfig("serve", "decode", max_len,
                                           max_batch))
        # cache_spec: "layout[:shards]/dtype" (models/cache.py) forces the
        # KV-cache layout; None defers to the layout policy's product
        # decision (when mesh= was given), else the config's own spec.
        spec = cache_spec
        if spec is None and self.layout_decision is not None:
            spec = self.layout_decision.cache_spec or None
        if spec and model.supports_cache_spec \
                and spec != model.cfg.cache_spec:
            from repro.models import build_model
            model = build_model(
                dataclasses.replace(model.cfg, cache_spec=spec))
            self.model = model    # params are spec-independent
        self.cache_spec = spec
        self.S = max_len
        from repro.models.param import is_def
        self.cache = jax.tree.map(
            lambda d: jnp.zeros(d.shape, d.dtype),
            model.cache_defs(max_batch, max_len), is_leaf=is_def)
        self._decode = jax.jit(self._decode_impl, donate_argnums=(1,))
        self._prefill = jax.jit(self._prefill_impl)

    # -- jitted kernels -------------------------------------------------
    def _prefill_impl(self, params, tokens):
        with self._rules_ctx():
            logits, cache = self.model.apply(params, {"tokens": tokens},
                                             mode="prefill")
        nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return nxt, cache

    def _decode_impl(self, params, cache, tokens, positions):
        with self._rules_ctx():
            logits, cache = self.model.apply(
                params, {"tokens": tokens, "positions": positions},
                mode="decode", cache=cache)
        nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return nxt, cache

    # -- slot management -------------------------------------------------
    def _admit(self):
        while self.queue and self.free:
            req = self.queue.pop(0)
            slot = self.free.pop(0)
            T = len(req.prompt)
            assert T < self.S, "prompt exceeds slot capacity"
            toks = jnp.asarray(np.asarray(req.prompt, np.int32)[None])
            nxt, pcache = self._prefill(self.params, toks)
            self._write_slot(slot, pcache, T)
            self._next = self._next.at[slot].set(int(nxt[0]))
            self.lengths[slot] = T
            req.out.append(int(nxt[0]))
            self.live[slot] = req

    def _write_slot(self, slot: int, pcache, true_len: int):
        """Scatter a single-sequence prefill cache (leaves (L, 1, ...)) into
        the batched cache (leaves (L, B, ...)) at `slot`; time-like axes are
        padded/cropped to the slot capacity."""
        def one(bc, pc):
            if bc.dtype == jnp.int32 and bc.ndim == 2:   # (L, B) lengths
                return bc.at[:, slot].set(jnp.minimum(pc[:, 0], true_len))
            src = pc[:, 0]                               # (L, ...)
            want = bc.shape[2:]
            if src.shape[1:] != want:                    # time axis differs
                width = min(src.shape[1], want[0])
                src = src[:, :width]
                pad = [(0, 0), (0, want[0] - width)] + \
                    [(0, 0)] * (src.ndim - 2)
                src = jnp.pad(src, pad)
            return bc.at[:, slot].set(src.astype(bc.dtype))

        self.cache = jax.tree.map(one, self.cache, pcache)

    # -- main tick --------------------------------------------------------
    def tick(self) -> list[Request]:
        """Admit waiting requests, run ONE batched decode step, return the
        requests that finished this tick."""
        self._admit()
        if not self.live:
            return []
        positions = jnp.asarray(self.lengths.reshape(self.B, 1), jnp.int32)
        nxt, self.cache = self._decode(
            self.params, self.cache, self._next[:, None], positions)
        self._next = nxt.astype(jnp.int32)
        finished = []
        for slot, req in list(self.live.items()):
            self.lengths[slot] += 1
            req.out.append(int(nxt[slot]))
            if len(req.out) >= req.max_new:
                req.done = True
                finished.append(req)
                del self.live[slot]
                self.free.append(slot)
        return finished


def _bucket(n: int) -> int:
    """Next power of two >= n: tail prefill chunks pad to a bucket so jit
    compiles O(log chunk) entries, not one per prompt length."""
    b = 1
    while b < n:
        b *= 2
    return b


class PagedServeLoop(_ServeBase):
    """Block-table paged KV cache + chunked/bucketed prefill (see module
    docstring).  ``num_blocks * block_size`` total cache positions are
    shared by up to ``max_batch`` concurrent sequences."""

    def __init__(self, model, params, *, max_batch: int = 4,
                 num_blocks: int = 64, block_size: int = 16,
                 chunk: int = 64, mesh=None, layout: str = "auto"):
        from repro.models.config import ShapeConfig
        assert model.supports_paged_cache, (
            f"{model.cfg.name}: paged serving needs a growing KV cache "
            f"(family={model.cfg.family}); use ServeLoop")
        assert chunk % block_size == 0, "chunk must be block-aligned"
        super().__init__(model, params, max_batch=max_batch, mesh=mesh,
                         layout=layout,
                         shape=ShapeConfig("serve", "decode",
                                           num_blocks * block_size,
                                           max_batch))
        self.alloc = BlockAllocator(num_blocks, block_size)
        self.bs = block_size
        self.nbmax = num_blocks            # a table can never exceed the pool
        self.chunk = chunk
        from repro.models.param import is_def
        defs = model.paged_cache_defs(max_batch, num_blocks, block_size,
                                      self.nbmax)
        full = jax.tree.map(lambda d: jnp.zeros(d.shape, d.dtype), defs,
                            is_leaf=is_def)
        # only the block pool lives on device between ticks; tables and
        # lengths are rebuilt from host truth every step
        self.pages = {"kp": full["kp"], "vp": full["vp"]}
        self.bt = np.zeros((max_batch, self.nbmax), np.int32)
        self._seq_of_slot: dict[int, int] = {}
        self._admit_order: list[int] = []   # slots, oldest first
        self._seq_counter = 0
        self.preemptions = 0
        self._decode = jax.jit(self._decode_impl, donate_argnums=(1,))
        self._chunk_prefill = jax.jit(self._chunk_impl, donate_argnums=(1,))

    # -- jitted kernels -------------------------------------------------
    def _stack(self, x):
        """Broadcast a per-slot host array across the layer axis (every
        layer shares one block table / length vector)."""
        L = self.model.cfg.num_layers
        return jnp.broadcast_to(x[None], (L,) + x.shape)

    def _decode_impl(self, params, pages, bt, tokens, positions):
        cache = {"kp": pages["kp"], "vp": pages["vp"],
                 "bt": self._stack(bt), "len": self._stack(positions[:, 0])}
        with self._rules_ctx():
            logits, cache = self.model.apply(
                params, {"tokens": tokens, "positions": positions},
                mode="decode", cache=cache)
        nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return nxt, {"kp": cache["kp"], "vp": cache["vp"]}

    def _chunk_impl(self, params, pages, tokens, positions, bt_row,
                    last_index):
        with self._rules_ctx():
            logits, pages = self.model.apply(
                params, {"tokens": tokens, "positions": positions,
                         "block_tables": bt_row, "last_index": last_index},
                mode="chunk_prefill", cache=pages)
        nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return nxt, pages

    # -- admission -------------------------------------------------------
    def _admit(self):
        while self.queue and self.free:
            req = self.queue[0]
            prompt = np.asarray(req.prompt, np.int32)
            T = len(prompt)
            if (T + 1 + self.bs - 1) // self.bs > self.alloc.num_blocks:
                raise RuntimeError(
                    f"prompt of {T} tokens can never fit the "
                    f"{self.alloc.num_blocks}x{self.bs} block pool")
            sid = self._seq_counter
            try:
                res = self.alloc.admit(sid, prompt.tolist(), reserve=1)
            except OutOfBlocks:
                if not self.live and not self._preempt_youngest(protect=-1):
                    raise RuntimeError(
                        "admission stalled with no live sequences: "
                        "block pool exhausted by the prefix cache?")
                return                     # head-of-line waits for blocks
            self._seq_counter += 1
            self.queue.pop(0)
            slot = self.free.pop(0)
            self._seq_of_slot[slot] = sid
            self._admit_order.append(slot)
            self._set_table(slot, res.table)
            nxt = self._prefill_chunks(slot, prompt,
                                       res.n_shared_tokens, T)
            self._next = self._next.at[slot].set(int(nxt))
            self.lengths[slot] = T
            req.out.append(int(nxt))
            self.live[slot] = req

    def _set_table(self, slot: int, table: list[int]):
        self.bt[slot] = 0
        self.bt[slot, : len(table)] = table

    def _prefill_chunks(self, slot: int, prompt: np.ndarray, start: int,
                        T: int) -> int:
        """Stream prompt positions [start, T) through the pool in
        block-aligned chunks; the tail pads to a power-of-two bucket
        (positions -1 => writes dropped, logits taken at the last valid
        row).  `start` skips positions covered by shared prefix blocks --
        their K/V is already resident."""
        bt_row = jnp.asarray(self.bt[slot: slot + 1])
        pos = start
        nxt = None
        while pos < T:
            c = min(self.chunk, T - pos)
            cb = c if c == self.chunk else _bucket(c)
            toks = np.zeros((1, cb), np.int32)
            toks[0, :c] = prompt[pos: pos + c]
            pv = np.full((1, cb), -1, np.int32)
            pv[0, :c] = np.arange(pos, pos + c, dtype=np.int32)
            nxt, self.pages = self._chunk_prefill(
                self.params, self.pages, jnp.asarray(toks),
                jnp.asarray(pv), bt_row,
                jnp.asarray([c - 1], jnp.int32))
            pos += c
        return int(nxt[0])

    # -- eviction / preemption -------------------------------------------
    def _release(self, slot: int):
        self.alloc.finish(self._seq_of_slot.pop(slot))
        self._admit_order.remove(slot)
        self.bt[slot] = 0
        self.lengths[slot] = 0
        self.free.append(slot)

    def _preempt_youngest(self, protect: int) -> bool:
        """Requeue the most recently admitted live sequence (other than
        `protect`) at the FRONT of the queue, releasing its blocks.
        Greedy decode is deterministic, so re-running it from the prompt
        reproduces the same tokens."""
        for slot in reversed(self._admit_order):
            if slot == protect or slot not in self.live:
                continue
            req = self.live.pop(slot)
            req.out = []
            self.queue.insert(0, req)
            self._release(slot)
            self.preemptions += 1
            return True
        return False

    def _grow_tables(self):
        """Give every live slot a block for the position it writes this
        tick, preempting the youngest sequences when the pool is dry."""
        for slot in list(self.live):
            if slot not in self.live:
                continue
            sid = self._seq_of_slot[slot]
            while True:
                try:
                    if self.alloc.ensure_capacity(sid, int(self.lengths[slot])):
                        self._set_table(slot, self.alloc.table(sid))
                    break
                except OutOfBlocks:
                    if not self._preempt_youngest(protect=slot):
                        raise RuntimeError(
                            "block pool too small for a single sequence: "
                            f"{self.alloc.num_blocks} x {self.bs}")

    # -- main tick --------------------------------------------------------
    def tick(self) -> list[Request]:
        self._admit()
        if not self.live:
            return []
        self._grow_tables()
        # free slots decode with position -1: their K/V write is dropped
        # (paged_kv_write) and their output ignored
        positions = np.full(self.B, -1, np.int32)
        for slot in self.live:
            positions[slot] = self.lengths[slot]
        nxt, self.pages = self._decode(
            self.params, self.pages, jnp.asarray(self.bt),
            self._next[:, None], jnp.asarray(positions[:, None]))
        self._next = nxt.astype(jnp.int32)
        finished = []
        for slot, req in list(self.live.items()):
            self.lengths[slot] += 1
            req.out.append(int(nxt[slot]))
            if len(req.out) >= req.max_new:
                req.done = True
                finished.append(req)
                del self.live[slot]
                self._release(slot)
        return finished
