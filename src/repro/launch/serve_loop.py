"""Continuous-batching serve loop (vLLM-flavoured, beyond-paper).

A fixed pool of B slots shares one batched KV/state cache; requests join
mid-flight (prefill into a free slot), a single batched decode step runs
for ALL live slots each tick with PER-SLOT positions (ragged batch -- see
the vmapped cache writes in models/layers.py), and finished slots are
recycled.  Prefill compiles once per distinct prompt length (callers can
bucket prompts if they need a tighter jit cache).

CPU-runnable at smoke scale; the same loop drives TPU serving, with the
weight layout (stationary / hybrid / fsdp) picked per model by the
memory-aware policy in repro.dist.policy -- pass `mesh=` to get an
analytic decision, or `layout=` to force one.
"""
from __future__ import annotations

import contextlib
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (T,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeLoop:
    def __init__(self, model, params, *, max_batch: int = 4,
                 max_len: int = 512, mesh=None, layout: str = "auto"):
        self.model = model
        self.params = params
        self.B = max_batch
        self.S = max_len
        self.layout_decision = None
        self.rules = None
        self.mesh = mesh
        if layout != "auto":
            from repro.dist.sharding import serve_layout_rules
            self.rules = serve_layout_rules(layout)
        elif mesh is not None:
            from repro.dist import policy as dist_policy
            from repro.models.config import ShapeConfig
            self.layout_decision = dist_policy.analytic_serve_decision(
                model, ShapeConfig("serve", "decode", max_len, max_batch),
                mesh)
            self.rules = self.layout_decision.rules
        from repro.models.param import is_def
        self.cache = jax.tree.map(
            lambda d: jnp.zeros(d.shape, d.dtype),
            model.cache_defs(max_batch, max_len), is_leaf=is_def)
        self.live: dict[int, Request] = {}   # slot -> request
        self.free = list(range(max_batch))
        self.queue: list[Request] = []
        self.lengths = np.zeros(max_batch, np.int64)  # host-side truth
        self._next = jnp.zeros((max_batch,), jnp.int32)
        self._decode = jax.jit(self._decode_impl, donate_argnums=(1,))
        self._prefill = jax.jit(self._prefill_impl)

    # -- jitted kernels -------------------------------------------------
    def _rules_ctx(self):
        """Make the chosen layout's rules AND the mesh ambient while a
        step traces: constrain() in model code no-ops without an ambient
        mesh, so the layout only binds under both (plain nullcontext for
        CPU smoke tests with neither)."""
        stack = contextlib.ExitStack()
        if self.rules is not None:
            from repro.dist.sharding import use_rules
            stack.enter_context(use_rules(self.rules))
        if self.mesh is not None:
            stack.enter_context(self.mesh)
        return stack

    def _prefill_impl(self, params, tokens):
        with self._rules_ctx():
            logits, cache = self.model.apply(params, {"tokens": tokens},
                                             mode="prefill")
        nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return nxt, cache

    def _decode_impl(self, params, cache, tokens, positions):
        with self._rules_ctx():
            logits, cache = self.model.apply(
                params, {"tokens": tokens, "positions": positions},
                mode="decode", cache=cache)
        nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return nxt, cache

    # -- slot management -------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        while self.queue and self.free:
            req = self.queue.pop(0)
            slot = self.free.pop(0)
            T = len(req.prompt)
            assert T < self.S, "prompt exceeds slot capacity"
            toks = jnp.asarray(np.asarray(req.prompt, np.int32)[None])
            nxt, pcache = self._prefill(self.params, toks)
            self._write_slot(slot, pcache, T)
            self._next = self._next.at[slot].set(int(nxt[0]))
            self.lengths[slot] = T
            req.out.append(int(nxt[0]))
            self.live[slot] = req

    def _write_slot(self, slot: int, pcache, true_len: int):
        """Scatter a single-sequence prefill cache (leaves (L, 1, ...)) into
        the batched cache (leaves (L, B, ...)) at `slot`; time-like axes are
        padded/cropped to the slot capacity."""
        def one(bc, pc):
            if bc.dtype == jnp.int32 and bc.ndim == 2:   # (L, B) lengths
                return bc.at[:, slot].set(jnp.minimum(pc[:, 0], true_len))
            src = pc[:, 0]                               # (L, ...)
            want = bc.shape[2:]
            if src.shape[1:] != want:                    # time axis differs
                width = min(src.shape[1], want[0])
                src = src[:, :width]
                pad = [(0, 0), (0, want[0] - width)] + \
                    [(0, 0)] * (src.ndim - 2)
                src = jnp.pad(src, pad)
            return bc.at[:, slot].set(src.astype(bc.dtype))

        self.cache = jax.tree.map(one, self.cache, pcache)

    # -- main tick --------------------------------------------------------
    def tick(self) -> list[Request]:
        """Admit waiting requests, run ONE batched decode step, return the
        requests that finished this tick."""
        self._admit()
        if not self.live:
            return []
        positions = jnp.asarray(self.lengths.reshape(self.B, 1), jnp.int32)
        nxt, self.cache = self._decode(
            self.params, self.cache, self._next[:, None], positions)
        self._next = nxt.astype(jnp.int32)
        finished = []
        for slot, req in list(self.live.items()):
            self.lengths[slot] += 1
            req.out.append(int(nxt[slot]))
            if len(req.out) >= req.max_new:
                req.done = True
                finished.append(req)
                del self.live[slot]
                self.free.append(slot)
        return finished

    def run_until_drained(self, max_ticks: int = 10_000):
        done = []
        for _ in range(max_ticks):
            done += self.tick()
            if not self.live and not self.queue:
                break
        return done
