"""Step builders: island train step, FL-stacked (vmapped) train step,
prefill/decode serve steps, and the FL aggregation step.

These are the functions the dry-run lowers and the examples execute.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import federated
from repro.dist.sharding import constrain
from repro.optim import apply_updates, clip_by_global_norm


def lm_loss(model, params, batch):
    logits, aux = model.apply(params, batch, mode="train")
    labels = batch["labels"]
    cfg = model.cfg
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    # gold logit via a masked reduction, NOT take_along_axis: a gather over
    # the vocab-sharded logits would force SPMD to replicate (B,T,V).
    V = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(iota == labels[..., None],
                             logits.astype(jnp.float32), 0.0), axis=-1)
    mask = jnp.ones(labels.shape, jnp.float32)
    if cfg.frontend == "vision_stub":   # patch positions carry no labels
        mask = mask.at[:, : cfg.frontend_len].set(0.0)
    xent = jnp.sum((lse - gold) * mask) / jnp.maximum(mask.sum(), 1.0)
    total = xent + 0.01 * aux
    return total, {"xent": xent, "aux": jnp.asarray(aux, jnp.float32)}


def cnn_loss(model, params, batch):
    logits, aux = model.apply(params, batch, mode="train")
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               labels[..., None], axis=-1)[..., 0]
    xent = (lse - gold).mean()
    return xent, {"xent": xent, "aux": jnp.zeros((), jnp.float32)}


def _loss_for(model):
    return cnn_loss if model.cfg.family == "cnn" else lm_loss


def make_train_step(model, optimizer, *, clip_norm: float = 1.0):
    """One ISLAND-LOCAL train step (FSDP x TP SPMD inside the island):
    (params, opt_state, batch) -> (params, opt_state, metrics).
    Gradient accumulation scans cfg.grad_accum microbatches."""
    loss_fn = _loss_for(model)
    accum = max(1, model.cfg.grad_accum)

    def grads_of(params, batch):
        (loss, parts), grads = jax.value_and_grad(
            partial(loss_fn, model), has_aux=True)(params, batch)
        return loss, parts, grads

    def train_step(params, opt_state, batch):
        if accum == 1:
            loss, parts, grads = grads_of(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)

            def micro_step(acc, mb):
                loss_acc, g_acc = acc
                loss, parts, g = grads_of(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / accum, g_acc, g)
                return (loss_acc + loss / accum, g_acc), parts

            # accumulator DERIVED from params so it inherits their (FSDP)
            # sharding: an unconstrained zeros tree lets GSPMD replicate it,
            # turning the per-microbatch reduce-scatter into a full
            # all-reduce of fp32 grads (~9x collective bytes, measured).
            g0 = jax.tree.map(
                lambda p: (p * 0).astype(jnp.float32), params)
            (loss, grads), parts_all = jax.lax.scan(
                micro_step, (jnp.zeros((), jnp.float32), g0), micro)
            parts = jax.tree.map(lambda x: x.mean(), parts_all)

        grads, grad_norm = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {"loss": loss.astype(jnp.float32),
                   "grad_norm": grad_norm,
                   **{k: v for k, v in parts.items()}}
        return params, opt_state, metrics

    return train_step


def make_fl_train_step(model, optimizer, n_islands: int, **kw):
    """FL-stacked step: leading island axis on params/opt_state/batch,
    sharded over the `pod` mesh axis (one federated island per pod)."""
    step = make_train_step(model, optimizer, **kw)
    if n_islands == 1:
        return step
    return jax.vmap(step, in_axes=(0, 0, 0), out_axes=0,
                    spmd_axis_name="pod")


def make_fl_aggregate(compress=False, *, k_frac: float = 0.05):
    """(stacked_params, mixing (P,P)) -> mixed stacked_params.  The paper's
    whole weight-exchange round as one collective over the pod axis.

    compress: False/"none" -> raw exchange (storage dtype on the wire);
    True/"q8", "topk", "q8_topk" (dashes accepted) -> the compressed
    delta exchange, signature (stacked, base, mixing)."""
    mode = {False: "none", None: "none", True: "q8"}.get(compress, compress)
    mode = mode.replace("-", "_")
    if mode == "none":
        return federated.fl_aggregate
    return partial(federated.fl_aggregate_compressed, mode=mode,
                   k_frac=k_frac)


def make_prefill_step(model):
    def prefill_step(params, batch):
        logits, cache = model.apply(params, batch, mode="prefill")
        next_tok = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return next_tok, cache
    return prefill_step


def make_chunk_prefill_step(model):
    """One chunk of CONTIGUOUS chunked prefill: (params, batch, cache) ->
    (next_tok, cache).  `batch` carries the chunk's tokens, their absolute
    positions, and last_index (the final valid position, for tail chunks
    padded to the chunk length); `cache` is the spec'd contiguous KV cache
    from models/cache.py, donated like the decode cache.  Streaming a long
    prompt through fixed-size chunks bounds prefill temporaries (weight
    gathers, MoE dispatch) to one chunk while the resident cache keeps its
    CacheSpec footprint -- the fit story for temp-dominated prefill cells."""
    def chunk_prefill_step(params, batch, cache):
        logits, cache = model.apply(params, batch, mode="chunk_prefill",
                                    cache=cache)
        next_tok = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return next_tok, cache
    return chunk_prefill_step


def make_decode_step(model):
    def decode_step(params, batch, cache):
        logits, cache = model.apply(params, batch, mode="decode", cache=cache)
        next_tok = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return next_tok, cache
    return decode_step
