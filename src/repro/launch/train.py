"""Federated training launcher (Tier B semantics, any scale).

On CPU this runs the REAL production path at smoke scale: P virtual islands
(vmapped, leading island axis), E local steps between weight exchanges, the
exchange as one mixing collective, straggler-driven selection, int8
compression, checkpoints + resume.  On a TPU pod the same script runs with
--mesh production (the pod axis becomes the island axis).

  PYTHONPATH=src python -m repro.launch.train --arch granite-20b --smoke \
      --steps 60 --islands 2 --local-steps 5 --ckpt-dir /tmp/flight_ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.core import aggregation
from repro.core import faults as faults_mod
from repro.core import federated as fed
from repro.data.synthetic import batch_token_stream, make_token_stream
from repro.launch.steps import make_fl_aggregate, make_fl_train_step
from repro.models import build_model
from repro.optim import adamw, cosine_warmup


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-20b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--islands", type=int, default=2)
    ap.add_argument("--local-steps", type=int, default=4,
                    help="E: train steps between FL exchanges")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress", nargs="?", const="q8", default="none",
                    choices=["none", "q8", "topk", "q8-topk"],
                    help="delta compression on the exchange (bare flag "
                         "keeps the old int8 behaviour = q8)")
    ap.add_argument("--topk-frac", type=float, default=0.05,
                    help="kept fraction for the topk compression modes")
    ap.add_argument("--overlap", action="store_true",
                    help="double-buffer the exchange: dispatch round r's "
                         "mixing collective, run round r+1's first local "
                         "step concurrently, then merge (1-step-stale "
                         "exchange; federated.fl_overlap_merge)")
    ap.add_argument("--fog-cells", type=int, default=1,
                    help="two-tier exchange: islands aggregate within fog "
                         "cells, then across cells (== flat for matching "
                         "weights; core/hierarchy.py)")
    ap.add_argument("--straggler-slack", type=float, default=3.0)
    ap.add_argument("--byzantine", type=float, default=0.0,
                    help="fraction of islands that ship corrupted updates "
                         "into every exchange (seeded faults.FaultPlan)")
    ap.add_argument("--byzantine-attacks", default="sign_flip,scale",
                    help="comma list from faults.ATTACKS")
    ap.add_argument("--byzantine-scale", type=float, default=10.0)
    ap.add_argument("--robust-agg", default="none",
                    choices=("none",) + aggregation.ROBUST_METHODS,
                    help="swap the weighted mixing collective for a "
                         "Byzantine-robust fold of the island models")
    ap.add_argument("--trim-frac", type=float, default=0.2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    P = args.islands
    compress = args.compress.replace("-", "_")
    opt = adamw(cosine_warmup(args.lr, 10, args.steps))
    step = jax.jit(make_fl_train_step(model, opt, P))
    agg = jax.jit(make_fl_aggregate(compress=compress,
                                    k_frac=args.topk_frac))
    merge = jax.jit(fed.fl_overlap_merge)
    clock = fed.IslandClock(P)

    params = model.init(jax.random.key(args.seed))
    opt_state = opt.init(params)
    if P > 1:
        params = fed.stack_islands(params, P)
        opt_state = fed.stack_islands(opt_state, P)

    plan = None
    if args.byzantine > 0 and P > 1:
        plan = faults_mod.FaultPlan(faults_mod.FaultConfig(
            byzantine_frac=args.byzantine,
            attacks=tuple(args.byzantine_attacks.split(",")),
            scale_factor=args.byzantine_scale, seed=args.seed))
        print(f"[train] byzantine islands: {plan.byzantine_in(range(P))}")

    base_params = jax.tree.map(lambda x: x, params)  # last-sync base
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if mgr and args.resume and mgr.latest_step() is not None:
        start, params, opt_state, extra = mgr.restore(
            params_like=params, opt_state_like=opt_state)
        params = jax.tree.map(jnp.asarray, params)
        opt_state = jax.tree.map(jnp.asarray, opt_state)
        print(f"[train] resumed from step {start}")

    streams = [make_token_stream(cfg.vocab_size, 400_000, seed=args.seed + i)
               for i in range(P)]
    n_data = np.array([len(s) for s in streams], np.float64)

    def batch_at(s):
        xs, ys = [], []
        for i in range(P):
            x, y = batch_token_stream(streams[i], args.batch, args.seq, s)
            xs.append(x)
            ys.append(y)
        b = {"tokens": jnp.asarray(np.stack(xs)),
             "labels": jnp.asarray(np.stack(ys))}
        if P == 1:
            b = jax.tree.map(lambda v: v[0], b)
        return b

    def dispatch_exchange(cur_params, sel):
        """Issue this round's mixing collective (async under jax dispatch).
        Returns (mixed_params | None, tag)."""
        w = (n_data / n_data.sum()) * sel
        if w.sum() <= 0:               # nobody selected -> no exchange
            return None, "no-exchange"
        if args.fog_cells > 1:
            # edge->fog->cloud: two narrow mixing hops instead of one
            # P-wide collective (identical result; tests/test_hierarchy).
            # With compression the edge hop's collective is CELL-LOCAL.
            from repro.core import hierarchy
            cell_of = np.arange(P) % args.fog_cells
            mixed = hierarchy.hierarchical_sync_aggregate(
                cur_params, w, cell_of, compress=compress,
                base_params=base_params if compress != "none" else None,
                k_frac=args.topk_frac)
            tag = f"fog-exchange x{args.fog_cells}"
        else:
            M = jnp.asarray(
                fed.selection_mixing(n_data / n_data.sum(), sel),
                jnp.float32)
            if compress != "none":
                mixed = agg(cur_params, base_params, M)
            else:
                mixed = agg(cur_params, M)
            tag = "exchange"
        if compress != "none":
            tag += f"+{args.compress}"
        return mixed, tag

    def robust_exchange(cur_params, ok: np.ndarray):
        """Byzantine-robust fold of the finite island models; every island
        receives the fold (no mixing matrix an attacker could dominate).
        With --compress the members are first round-tripped through the
        compressed delta wire (per-island payloads) and the quarantine
        gate re-runs on the DECOMPRESSED deltas -- the fold must see and
        threshold what the wire carries, not full-precision local
        weights."""
        tag = f"robust-exchange:{args.robust_agg}"
        if compress != "none":
            from repro.core import compression as comp
            cur_params = comp.roundtrip_islands(
                cur_params, base_params, mode=compress,
                k_frac=args.topk_frac)
            ok = ok & np.asarray(faults_mod.finite_members(cur_params))
            tag += f"+{args.compress}"
        keep = np.flatnonzero(ok)
        if keep.size == 0:
            return None, "no-exchange"
        sub = jax.tree.map(lambda l: l[np.asarray(keep)], cur_params)
        kw = dict(trim_frac=args.trim_frac,
                  base=fed.island_slice(base_params, 0))
        if args.fog_cells > 1:
            from repro.core import hierarchy
            agg_t = hierarchy.hierarchical_robust_aggregate(
                sub, keep % args.fog_cells, args.robust_agg, **kw)
        else:
            agg_t = aggregation.robust_aggregate_stacked(
                sub, args.robust_agg, **kw)
        mixed = jax.tree.map(
            lambda a, l: jnp.broadcast_to(a.astype(l.dtype)[None], l.shape),
            agg_t, cur_params)
        return mixed, tag

    def exchange_input(cur_params, rnd: int):
        """What the aggregator SEES: Byzantine islands corrupt their update
        on the wire (honest islands' local state is never touched)."""
        if plan is None:
            return cur_params, np.ones(P, bool)
        out = cur_params
        for i in plan.byzantine_in(range(P)):
            sub = plan.corrupt(fed.island_slice(out, i),
                               fed.island_slice(base_params, i), i, rnd)
            out = jax.tree.map(lambda l, c: l.at[i].set(c), out, sub)
        # sanitization gate: a non-finite update never reaches the fold.
        # Zero selection weight is NOT enough for the weighted collective
        # (0 * nan = nan in the tensordot), so the rejected islands'
        # slices are also replaced by their last-sync base.
        ok = faults_mod.finite_members(out)
        if not ok.all():
            bad = jnp.asarray(~ok)
            out = jax.tree.map(
                lambda l, b: jnp.where(
                    bad.reshape((-1,) + (1,) * (l.ndim - 1)), b, l),
                out, base_params)
        return out, ok

    pending = None   # (mixed, snapshot) while an overlapped exchange flies
    for s in range(start, args.steps):
        t0 = time.time()
        params, opt_state, metrics = step(params, opt_state, batch_at(s))
        tag = "local"
        if pending is not None:
            # round r's collective was in flight during this step (it ran
            # from the snapshot): fold the exchange correction in without
            # recomputing the step (1-step-stale exchange)
            mixed, snap = pending
            params = merge(params, mixed, snap)
            base_params = mixed
            pending = None
            tag = "local+merge"
        jax.block_until_ready(metrics["loss"])
        dt = time.time() - t0
        clock.observe(np.full(P, dt))  # per-island step times (uniform on CPU)
        loss = np.asarray(metrics["loss"]).mean()
        if (s + 1) % args.local_steps == 0 and P > 1:
            sel = clock.selection(args.straggler_slack)
            ex_in, ok = exchange_input(params, (s + 1) // args.local_steps)
            if args.robust_agg != "none":
                mixed, tag = robust_exchange(ex_in, ok)
            else:
                mixed, tag = dispatch_exchange(ex_in, sel * ok)
            if mixed is None:
                pass
            elif args.overlap and s + 1 < args.steps:
                pending = (mixed, params)  # merge lands after next step
                tag += "+overlap"
            else:
                params = mixed
                base_params = mixed
        print(f"[train] step={s+1} loss={loss:.4f} {dt*1e3:.0f}ms {tag}",
              flush=True)
        if mgr and (s + 1) % args.ckpt_every == 0:
            mgr.save(s + 1, params=params, opt_state=opt_state,
                     extra={"arch": args.arch, "islands": P})
            print(f"[train] checkpoint @ {s+1}")
    print("[train] done")


if __name__ == "__main__":
    main()
