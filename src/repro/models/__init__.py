from repro.models.model_factory import build_model, Model
