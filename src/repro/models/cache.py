"""CacheSpec: THE one place the KV-cache convention lives.

Every question about the decode cache -- what leaves it has, their
shapes, dtypes and logical sharding axes, how many bytes it costs on a
mesh, how a prefill packs one and how a decode step writes one -- is
answered here.  models/transformer.py, models/model_factory.py,
models/layers.py, dist/policy.py and launch/dryrun.py all used to carry
their own copy of this convention; they now delegate.

A CacheSpec is `layout[:shards]/dtype`:

  layout  "replicated" -- no head or seq sharding (the old silent
                          fallback, now an explicit choice);
          "head"       -- kv heads shard over "model" (canonical TP
                          decode; silently == replicated when
                          kv_heads %% model != 0, which resolve() turns
                          into an explicit ring fallback);
          "ring"       -- the SEQUENCE dim shards over "model" (context
                          parallelism): each shard owns S/n cache slots
                          and decode merges per-segment softmax partials
                          via log-sum-exp (layers.ring_decode_attention).
                          Always divides (seq lengths are 2^k), so it is
                          the fallback when head-sharding can't;
          "paged"      -- the block-pool cache (core/paging.py).
  shards  ring only: the static segment count; 0 = the ambient mesh's
          "model" axis size at trace time.
  dtype   "bf16", or "int8" -- rowwise-quantised K/V (kernels/quant8,
          per (token, head) fp32 scales over head_dim) with dequant
          fused into the attention reads by XLA.  Halves cache HBM
          (+ ~3%% scale overhead) at a <=1e-2 logit cost
          (tests/test_cache_spec.py pins the parity).

The spec is owned by the model config (`ModelConfig.cache_spec`, default
"auto" == "head/bf16" == the historical behaviour); the serve policy
(dist/policy.py) scores (weight layout x cache spec) products and
launchers thread the winning spec back in via
`dataclasses.replace(cfg, cache_spec=...)`.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models.param import pdef

CACHE_LAYOUTS = ("replicated", "head", "ring", "paged")
CACHE_DTYPES = ("bf16", "int8")

#: decode headroom appended to non-windowed prefill caches (slots for
#: subsequently generated tokens).  Historically lived in models/layers.
PREFILL_DECODE_MARGIN = 128


@dataclasses.dataclass(frozen=True)
class CacheSpec:
    layout: str = "head"
    dtype: str = "bf16"
    shards: int = 0          # ring segment count; 0 = ambient "model" size

    def __post_init__(self):
        if self.layout not in CACHE_LAYOUTS:
            raise ValueError(f"unknown cache layout '{self.layout}'; "
                             f"known: {CACHE_LAYOUTS}")
        if self.dtype not in CACHE_DTYPES:
            raise ValueError(f"unknown cache dtype '{self.dtype}'; "
                             f"known: {CACHE_DTYPES}")
        if self.shards and self.layout != "ring":
            raise ValueError("shards only applies to the ring layout")

    @property
    def quantized(self) -> bool:
        return self.dtype == "int8"

    @property
    def name(self) -> str:
        s = f":{self.shards}" if self.shards else ""
        return f"{self.layout}{s}/{self.dtype}"

    @classmethod
    def parse(cls, s) -> "CacheSpec":
        """"auto" | "layout[:shards]/dtype" | CacheSpec (passthrough)."""
        if isinstance(s, cls):
            return s
        if s is None or s == "auto":
            return cls()
        layout, _, dtype = str(s).partition("/")
        layout, _, shards = layout.partition(":")
        return cls(layout=layout, dtype=dtype or "bf16",
                   shards=int(shards) if shards else 0)


def spec_of(cfg) -> CacheSpec:
    """The model config's cache spec (ModelConfig.cache_spec string)."""
    return CacheSpec.parse(getattr(cfg, "cache_spec", "auto"))


# ---------------------------------------------------------------------------
# Logical axes + abstract leaves
# ---------------------------------------------------------------------------

def kv_axes(spec: CacheSpec):
    """Logical axes of one (batch, seq, kv_heads, head_dim) cache leaf.

    ring puts an EXPLICIT ("model",) tuple on the seq dim: explicit
    tuples bind in resolution pass 0 (dist/sharding.py), so "model" is
    claimed before the kv_heads priority wave can take it and the heads
    dim falls back to replicated -- exactly the ring contract.
    """
    if spec.layout == "ring":
        return ("batch", ("model",), "kv_heads", None)
    if spec.layout == "replicated":
        return ("batch", "kv_seq", None, None)
    return ("batch", "kv_seq", "kv_heads", None)


def ring_segments(spec: CacheSpec, seq_len: int) -> int:
    """Static ring segment count for a cache of `seq_len` slots: the
    spec's shard count (ambient "model" size when unset), reduced to the
    largest power-of-two divisor of seq_len so no slot padding is ever
    needed (padded slots would need masking against uninitialised keys).
    """
    if spec.layout != "ring":
        return 1
    from repro.dist.sharding import mesh_axis_size
    n = spec.shards or mesh_axis_size("model")
    while n > 1 and seq_len % n:
        n //= 2
    return max(n, 1)


def attention_cache_defs(cfg, batch: int, seq_len: int,
                         spec: CacheSpec | str | None = None):
    """Abstract KV-cache leaves (per layer) under a CacheSpec.

    bf16: {k, v, len}; int8 adds per-(token, head) fp32 scales
    {k_scale, v_scale} over the head_dim axis (rowwise layout of
    kernels/quant8: q keeps the cache's shape and therefore its
    sharding).
    """
    spec = CacheSpec.parse(spec) if spec is not None else spec_of(cfg)
    keep = min(cfg.window, seq_len) if cfg.window else seq_len
    ax = kv_axes(spec)
    kv = (batch, keep, cfg.num_kv_heads, cfg.head_dim)
    kv_dtype = jnp.int8 if spec.quantized else jnp.bfloat16
    d = {
        "k": pdef(kv, ax, dtype=kv_dtype, init="zeros"),
        "v": pdef(kv, ax, dtype=kv_dtype, init="zeros"),
        "len": pdef((batch,), ("batch",), dtype=jnp.int32, init="zeros"),
    }
    if spec.quantized:
        sc = (batch, keep, cfg.num_kv_heads, 1)
        d["k_scale"] = pdef(sc, ax, dtype=jnp.float32, init="zeros")
        d["v_scale"] = pdef(sc, ax, dtype=jnp.float32, init="zeros")
    return d


def paged_attention_cache_defs(cfg, batch, num_blocks, block_size,
                               max_blocks_per_seq):
    """Abstract paged-cache leaves (per layer): one block POOL shared by
    ALL sequences plus per-slot block tables and lengths.  Unlike the
    contiguous cache, HBM scales with the pool (total tokens resident),
    not max_batch * max_len.  The pool is bf16 + head-sharded only (the
    block dim hosts scatter writes, which GSPMD cannot shard without
    gathering the pool)."""
    kv = (num_blocks, block_size, cfg.num_kv_heads, cfg.head_dim)
    ax = (None, None, "kv_heads", None)
    return {
        "kp": pdef(kv, ax, dtype=jnp.bfloat16, init="zeros"),
        "vp": pdef(kv, ax, dtype=jnp.bfloat16, init="zeros"),
        "bt": pdef((batch, max_blocks_per_seq), ("batch", None),
                   dtype=jnp.int32, init="zeros"),
        "len": pdef((batch,), ("batch",), dtype=jnp.int32, init="zeros"),
    }


# ---------------------------------------------------------------------------
# Mesh resolution (policy-side): which specs are real on a given mesh
# ---------------------------------------------------------------------------

def resolve(spec: CacheSpec | str, cfg, mesh) -> tuple[CacheSpec, str]:
    """Effective spec on `mesh` + a note when the request degrades.

    "head" with kv_heads %% model != 0 cannot head-shard; the resolver
    reports it (the old code replicated ~100 GB/dev silently -- see
    dist/sharding.ShardingFallbackWarning) and callers offer "ring"
    as the candidate that always divides.
    """
    spec = CacheSpec.parse(spec)
    sizes = {str(k): int(v) for k, v in dict(mesh.shape).items()}
    m = sizes.get("model", 1)
    if spec.layout == "head" and m > 1 and cfg.num_kv_heads % m:
        return spec, (f"kv_heads={cfg.num_kv_heads} % model={m} != 0: "
                      f"head layout degrades to replicated ({m}-way "
                      f"replication of the cache); use ring")
    if spec.layout == "ring":
        n = spec.shards or m
        if n <= 1:
            return spec, "ring with a 1-wide model axis == replicated"
    return spec, ""


def cache_bytes(cfg, batch: int, seq_len: int,
                spec: CacheSpec | str | None, mesh, rules=None,
                num_layers: int | None = None) -> float:
    """Analytic per-device cache bytes for a spec on a mesh: the leaf
    defs resolved through the sharding rules, summed over layers.  This
    is the number dist/policy.py scores (weight x cache) products with
    and launch/dryrun.py records as `cache_bytes_analytic`."""
    from repro.dist.policy import sharded_bytes
    per_layer = attention_cache_defs(cfg, batch, seq_len, spec)
    L = num_layers if num_layers is not None else cfg.num_layers
    return sharded_bytes(per_layer, mesh, rules) * L


# ---------------------------------------------------------------------------
# Quantised read/write (rowwise int8 over head_dim; kernels/quant8)
# ---------------------------------------------------------------------------

def _q8_impl() -> str:
    # the Pallas rowwise kernel on TPU; the jnp reference elsewhere
    # (pallas_call is opaque to GSPMD partitioning, so SPMD CPU dryruns
    # must trace the pure-jnp path)
    return "auto" if jax.default_backend() == "tpu" else "ref"


def quantize_kv(x):
    """(..., D) bf16 -> ((...,D) int8, (...,1) fp32 scales)."""
    from repro.kernels.quant8 import ops
    return ops.quantize_rowwise(x, impl=_q8_impl())


def dequantize_kv(q, scale, out_dtype=jnp.bfloat16):
    """Inverse of quantize_kv.  XLA fuses the convert+scale into the
    attention einsum that consumes it, so the bf16 cache never
    materialises in HBM on the fused path."""
    from repro.kernels.quant8 import ops
    return ops.dequantize_rowwise(q, scale, out_dtype=out_dtype,
                                  impl=_q8_impl())


def read_kv(cache):
    """Cache leaves -> (k, v) bf16 views (dequantised when int8)."""
    if "k_scale" in cache:
        return (dequantize_kv(cache["k"], cache["k_scale"]),
                dequantize_kv(cache["v"], cache["v_scale"]))
    return cache["k"], cache["v"]


# ---------------------------------------------------------------------------
# Prefill pack + decode write (the two places a cache is produced)
# ---------------------------------------------------------------------------

def _pad_seq(x, target):
    pad = target - x.shape[1]
    if pad <= 0:
        return x
    return jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))


def pack_prefill_cache(cfg, kk, vv, *, window: int,
                       spec: CacheSpec | None = None):
    """Pack full-sequence K/V (B, T, Hkv, D) into a fresh decode cache.

    window: ring-buffer trim to the last `window` positions (decode
    overwrites slot len %% window); else pad PREFILL_DECODE_MARGIN slots
    of decode headroom, rounded up so ring segment counts divide.
    """
    spec = spec or spec_of(cfg)
    B, T = kk.shape[0], kk.shape[1]
    if window and T >= window:
        kk, vv = kk[:, -window:], vv[:, -window:]
        keep = window
    else:
        keep = T + PREFILL_DECODE_MARGIN
        n = spec.shards if spec.layout == "ring" else 0
        if n:
            keep = -(-keep // n) * n
    cache = {"len": jnp.full((B,), T, jnp.int32)}
    if spec.quantized:
        kq, ks = quantize_kv(kk)
        vq, vs = quantize_kv(vv)
        cache.update(k=_pad_seq(kq, keep), v=_pad_seq(vq, keep),
                     k_scale=_pad_seq(ks, keep), v_scale=_pad_seq(vs, keep))
    else:
        cache.update(k=_pad_seq(kk, keep), v=_pad_seq(vv, keep))
    return constrain_cache(cache, spec)


def write_kv(cache, kk, vv, slots, *, spec: CacheSpec):
    """Write K/V rows (B, C, Hkv, D) at per-batch `slots` (vmapped
    dynamic_update_slice: sequences at different positions coexist in
    one batch -- continuous batching).  C == 1 for decode steps; chunked
    prefill writes whole chunks.  Quantisation follows the CACHE's own
    leaves (an int8 cache carries k_scale/v_scale), so a bf16 cache
    built before a spec change still round-trips."""
    upd = jax.vmap(
        lambda c, u, s: jax.lax.dynamic_update_slice_in_dim(c, u, s, 0))
    out = dict(cache)
    if "k_scale" in cache:
        kq, ks = quantize_kv(kk)
        vq, vs = quantize_kv(vv)
        out["k"] = upd(cache["k"], kq.astype(cache["k"].dtype), slots)
        out["v"] = upd(cache["v"], vq.astype(cache["v"].dtype), slots)
        out["k_scale"] = upd(cache["k_scale"], ks, slots)
        out["v_scale"] = upd(cache["v_scale"], vs, slots)
    else:
        out["k"] = upd(cache["k"], kk.astype(cache["k"].dtype), slots)
        out["v"] = upd(cache["v"], vv.astype(cache["v"].dtype), slots)
    return constrain_cache(out, spec)


def constrain_cache(cache, spec: CacheSpec | str | None):
    """Re-assert the spec's sharding on freshly written cache leaves."""
    from repro.dist.sharding import constrain
    spec = CacheSpec.parse(spec) if spec is not None else CacheSpec()
    ax = kv_axes(spec)
    out = dict(cache)
    for key in ("k", "v", "k_scale", "v_scale"):
        if key in out:
            out[key] = constrain(out[key], ax)
    return out
