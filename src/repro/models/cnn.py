"""Small CNN / MLP classifiers -- the paper's own Tier-A workload
(MNIST / CIFAR-10 style federated training on heterogeneous workers)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.param import pdef


def cnn_defs(cfg):
    chans = cfg.cnn_channels or (16, 32)
    c_in = cfg.img_c
    defs = {}
    for i, c_out in enumerate(chans):
        defs[f"conv{i}_w"] = pdef((3, 3, c_in, c_out), (None, None, None, None),
                                  dtype=jnp.float32, fan_in_axes=(0, 1, 2))
        defs[f"conv{i}_b"] = pdef((c_out,), (None,), dtype=jnp.float32,
                                  init="zeros")
        c_in = c_out
    hw = cfg.img_hw // (2 ** len(chans))
    flat = hw * hw * c_in
    defs["fc_w"] = pdef((flat, cfg.n_classes), (None, None), dtype=jnp.float32,
                        fan_in_axes=(0,))
    defs["fc_b"] = pdef((cfg.n_classes,), (None,), dtype=jnp.float32,
                        init="zeros")
    return defs


def cnn_apply(params, cfg, batch_inputs, *, mode="train", cache=None):
    """batch_inputs: {"images": (B,H,W,C) float32}. Returns (logits, 0.0)."""
    x = batch_inputs["images"].astype(jnp.float32)
    chans = cfg.cnn_channels or (16, 32)
    for i in range(len(chans)):
        x = lax.conv_general_dilated(
            x, params[f"conv{i}_w"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.nn.relu(x + params[f"conv{i}_b"])
        x = lax.reduce_window(x, -jnp.inf, lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    x = x.reshape(x.shape[0], -1)
    logits = x @ params["fc_w"] + params["fc_b"]
    return logits, 0.0


def mlp_classifier_defs(cfg):
    d_in = cfg.img_hw * cfg.img_hw * cfg.img_c
    h = cfg.d_model or 128
    return {
        "w1": pdef((d_in, h), (None, None), dtype=jnp.float32, fan_in_axes=(0,)),
        "b1": pdef((h,), (None,), dtype=jnp.float32, init="zeros"),
        "w2": pdef((h, cfg.n_classes), (None, None), dtype=jnp.float32,
                   fan_in_axes=(0,)),
        "b2": pdef((cfg.n_classes,), (None,), dtype=jnp.float32, init="zeros"),
    }


def mlp_classifier_apply(params, cfg, batch_inputs, *, mode="train", cache=None):
    x = batch_inputs["images"].astype(jnp.float32)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["w1"] + params["b1"])
    return x @ params["w2"] + params["b2"], 0.0
