"""Architecture config dataclass covering all assigned model families."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio | cnn
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25

    # --- attention flavour ---
    attention: str = "full"          # full | swa (sliding) | local
    window: int = 0                  # swa/local window size
    qkv_bias: bool = False
    rope_fraction: float = 1.0       # chatglm applies RoPE to half the head dim
    rope_theta: float = 10_000.0

    # --- ssm / hybrid ---
    ssm_state: int = 0               # mamba N
    ssm_expand: int = 2              # d_inner = expand * d_model
    conv_width: int = 4
    lru_width: int = 0               # RG-LRU recurrent width
    pattern_recurrent: int = 0       # hybrid: recurrent blocks per super-block
    pattern_attention: int = 0       # hybrid: attention blocks per super-block

    # --- enc-dec ---
    is_encdec: bool = False
    enc_layers: int = 0

    # --- modality frontend (STUB: precomputed embeddings per spec) ---
    frontend: str = "none"           # none | vision_stub | audio_stub
    frontend_len: int = 0            # patches / frames occupying seq prefix

    # --- KV-cache spec (models/cache.py owns the convention) ---
    # "auto" (== "head/bf16", the historical convention) or
    # "layout[:shards]/dtype", e.g. "ring/bf16", "ring:4/int8", "head/int8".
    # The serve policy overrides this per cell via dataclasses.replace.
    cache_spec: str = "auto"

    # --- misc ---
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "silu"                # silu (gated) | gelu (gated) | gelu_plain
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    grad_accum: int = 1              # microbatch accumulation steps in train_step
    remat: bool = True

    # cnn (paper Tier-A models)
    img_hw: int = 0
    img_c: int = 0
    cnn_channels: tuple = ()
    n_classes: int = 0

    def __post_init__(self):
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.family in ("hybrid",) and not self.lru_width:
            object.__setattr__(self, "lru_width", self.d_model)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve 500k+ contexts? (bounded state / windowed attn)"""
        return self.family in ("ssm", "hybrid") or self.attention in ("swa", "local")


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                        # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_serve(self) -> bool:
        return self.kind in ("prefill", "decode")


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}
