"""Encoder-decoder transformer backbone (seamless-m4t-large-v2).

The speech/audio frontend is a STUB per the assignment: `input_specs()`
provides precomputed frame embeddings (B, enc_len, d_model).  The decoder is
a standard causal transformer with cross-attention; decode mode uses a self
KV cache plus a static cross-attention K/V cache computed at prefill.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.sharding import constrain
from repro.models import layers as L
from repro.models.param import pdef, stack_defs

ENC_LEN_CAP = 4096  # frontend frames occupying the encoder (see DESIGN.md)


def enc_len_for(seq_len: int) -> int:
    return min(ENC_LEN_CAP, seq_len)


def _enc_block_defs(cfg):
    return {
        "ln1": L.norm_defs(cfg),
        "attn": L.attention_defs(cfg),
        "ln2": L.norm_defs(cfg),
        "mlp": L.mlp_defs(cfg),
    }


def _dec_block_defs(cfg):
    return {
        "ln1": L.norm_defs(cfg),
        "self_attn": L.attention_defs(cfg),
        "ln_x": L.norm_defs(cfg),
        "cross_attn": L.attention_defs(cfg),
        "ln2": L.norm_defs(cfg),
        "mlp": L.mlp_defs(cfg),
    }


def encdec_defs(cfg):
    return {
        "embed": L.embed_defs(cfg),
        "enc_layers": stack_defs(_enc_block_defs(cfg), cfg.enc_layers),
        "enc_norm": L.norm_defs(cfg),
        "dec_layers": stack_defs(_dec_block_defs(cfg), cfg.num_layers),
        "final_norm": L.norm_defs(cfg),
    }


def encode(params, cfg, frames):
    """frames: (B, Te, d) stub embeddings -> (B, Te, d) encoder states."""
    x = constrain(frames, ("batch", None, None))
    B, Te = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(Te, dtype=jnp.int32)[None], (B, Te))

    def body(x, lp):
        h = L.apply_norm(lp["ln1"], x, cfg.norm)
        a, _ = L.attention_apply(lp["attn"], cfg, h, positions,
                                 mode="train", causal=False)
        x = x + a
        h = L.apply_norm(lp["ln2"], x, cfg.norm)
        return x + L.mlp_apply(lp["mlp"], cfg, h), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = lax.scan(body, x, params["enc_layers"])
    return L.apply_norm(params["enc_norm"], x, cfg.norm)


def _dec_block(lp, cfg, x, positions, enc_out, mode, cache):
    h = L.apply_norm(lp["ln1"], x, cfg.norm)
    a, self_cache = L.attention_apply(
        lp["self_attn"], cfg, h, positions, mode=mode,
        cache=cache["self"] if cache else None)
    x = x + a
    h = L.apply_norm(lp["ln_x"], x, cfg.norm)
    if mode == "decode":
        a, cross_cache = L.attention_apply(
            lp["cross_attn"], cfg, h, positions, mode="decode",
            cache=cache["cross"], is_cross=True)
    else:
        a, _ = L.attention_apply(lp["cross_attn"], cfg, h, positions,
                                 mode="train", kv_source=enc_out)
        # build the static cross K/V cache at prefill
        cross_cache = None
        if mode == "prefill":
            kk = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wk"])
            vv = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wv"])
            cross_cache = {"k": kk, "v": vv,
                           "len": jnp.full((x.shape[0],), enc_out.shape[1],
                                           jnp.int32)}
    x = x + a
    h = L.apply_norm(lp["ln2"], x, cfg.norm)
    x = x + L.mlp_apply(lp["mlp"], cfg, h)
    ncache = None
    if mode != "train":
        ncache = {"self": self_cache, "cross": cross_cache}
    return x, ncache


def encdec_cache_defs(cfg, batch: int, seq_len: int):
    el = enc_len_for(seq_len)
    per_layer = {
        "self": L.attention_cache_defs(cfg, batch, seq_len),
        "cross": {
            "k": pdef((batch, el, cfg.num_kv_heads, cfg.head_dim),
                      ("batch", None, "kv_heads", "kv_head_dim"), init="zeros"),
            "v": pdef((batch, el, cfg.num_kv_heads, cfg.head_dim),
                      ("batch", None, "kv_heads", "kv_head_dim"), init="zeros"),
            "len": pdef((batch,), ("batch",), dtype=jnp.int32, init="zeros"),
        },
    }
    return stack_defs(per_layer, cfg.num_layers)


def encdec_apply(params, cfg, batch_inputs, *, mode="train", cache=None):
    """train/prefill: needs batch_inputs = {frames, tokens}.
    decode: {tokens (B,1)} + cache (encoder already folded into cross K/V)."""
    if mode == "decode":
        enc_out = None
    else:
        enc_out = encode(params, cfg, batch_inputs["frames"].astype(jnp.bfloat16))

    tokens = batch_inputs["tokens"]
    x = L.embed_apply(params["embed"], tokens)
    x = constrain(x, ("batch", None, None))
    B, T = x.shape[0], x.shape[1]

    if mode == "decode":
        positions = batch_inputs.get(
            "positions", cache["self"]["len"][0].reshape(B, 1))
    else:
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))

    def body(x, xs):
        lp, lc = xs if mode == "decode" else (xs, None)
        return _dec_block(lp, cfg, x, positions, enc_out, mode, lc)

    if cfg.remat and mode == "train":
        body = jax.checkpoint(body, prevent_cse=False)

    xs = (params["dec_layers"], cache) if mode == "decode" \
        else params["dec_layers"]
    x, new_cache = lax.scan(body, x, xs)

    if mode == "prefill":
        x = x[:, -1:]  # serving needs only the last position's logits
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed_apply(params["embed"], x)
    logits = constrain(logits, ("batch", None, "vocab"))
    if mode == "train":
        return logits, 0.0
    return logits, new_cache
