"""Core neural layers (pure JAX, shardable, scan-friendly).

Conventions:
  * activations bf16, softmax/normalisation statistics fp32;
  * attention tensors are (batch, seq, heads, head_dim);
  * every layer is a pure function  f(params_subtree, x, ...) -> y;
  * sequence lengths are static; decode uses a cache + scalar position.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.sharding import constrain, mesh_axis_size
from repro.models import cache as kvcache
from repro.models.param import pdef

# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def rmsnorm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def norm_defs(cfg, kind=None):
    kind = kind or cfg.norm
    d = {"scale": pdef((cfg.d_model,), (None,), init="ones")}
    if kind == "layernorm":
        d["bias"] = pdef((cfg.d_model,), (None,), init="zeros")
    return d


def apply_norm(p, x, kind="rmsnorm"):
    if "bias" in p:
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


# --------------------------------------------------------------------------
# Activations
# --------------------------------------------------------------------------

def act_fn(name):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "gelu_plain": jax.nn.gelu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]


# --------------------------------------------------------------------------
# RoPE (full + partial/"2d" fraction, as in ChatGLM)
# --------------------------------------------------------------------------

def rope_apply(x, positions, theta=10_000.0, fraction=1.0):
    """x: (..., T, H, D); positions: (..., T) int32. Rotates first
    `fraction*D` dims, passes the rest through (ChatGLM partial rotary)."""
    d = x.shape[-1]
    rot = int(d * fraction)
    rot -= rot % 2
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # positions (..., T) -> (..., T, 1, half): broadcast over heads
    ang = positions.astype(jnp.float32)[..., None, None] * freq
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate(
        [y1.astype(x.dtype), y2.astype(x.dtype), x_pass], axis=-1
    )


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------

# decode headroom appended to non-windowed prefill caches; the value (and
# every other cache convention) lives in models/cache.py
PREFILL_DECODE_MARGIN = kvcache.PREFILL_DECODE_MARGIN


def attention_full(q, k, v, *, causal=True, window=0, q_offset=0):
    """Exact attention with a materialised score matrix. Use for seq <= ~8k.

    q: (B,T,H,D)  k,v: (B,S,Hkv,D).  GQA via head grouping.
    """
    B, T, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, T, Hkv, G, D)
    scale = 1.0 / math.sqrt(D)
    scores = jnp.einsum("bthgd,bshd->bhgts", qg, k,
                        preferred_element_type=jnp.float32) * scale
    qpos = jnp.arange(T) + q_offset
    kpos = jnp.arange(S)
    mask = jnp.ones((T, S), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgts,bshd->bthgd", probs, v)
    return out.reshape(B, T, H, D)


def flash_attention_xla(q, k, v, *, causal=True, window=0, q_offset=0,
                        q_block=1024, kv_block=1024):
    """Memory-bounded blockwise attention (pure-XLA 'flash') with online
    softmax.  Never materialises (T,S) scores: peak extra memory is
    O(q_block * kv_block) per (batch, head).

    For sliding-window attention only ceil((window+q_block)/kv_block)+1 kv
    blocks are visited per q block (FLOPs proportional to the window).  For
    full causal attention the baseline visits the full rectangle with
    masking; the triangular schedule is a recorded perf iteration.
    """
    B, T, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    q_block = min(q_block, T)
    kv_block = min(kv_block, S)
    assert T % q_block == 0 and S % kv_block == 0
    nq, nkv = T // q_block, S // kv_block
    scale = 1.0 / math.sqrt(D)

    qg = q.reshape(B, nq, q_block, Hkv, G, D)

    if window:
        n_win = (window + q_block + kv_block - 2) // kv_block + 1
        n_win = min(n_win, nkv)
    else:
        n_win = nkv

    kpos_all = jnp.arange(S)

    def q_step(_, qi):
        qblk, iq = qi  # (B,Cq,Hkv,G,D), scalar block index
        qpos = iq * q_block + jnp.arange(q_block) + q_offset

        if window:
            lo = iq * q_block + q_offset - (window - 1)
            first = jnp.clip(lo // kv_block, 0, nkv - n_win)
        else:
            first = jnp.int32(0)

        def kv_step(carry, j):
            m, l, acc = carry
            jb = first + j
            kblk = lax.dynamic_slice_in_dim(k, jb * kv_block, kv_block, 1)
            vblk = lax.dynamic_slice_in_dim(v, jb * kv_block, kv_block, 1)
            kpos = jb * kv_block + jnp.arange(kv_block)
            s = jnp.einsum("bthgd,bshd->bhgts", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            msk = jnp.ones((q_block, kv_block), bool)
            if causal:
                msk &= kpos[None, :] <= qpos[:, None]
            if window:
                msk &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(msk[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgts,bshd->bhgtd", p.astype(q.dtype), vblk)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv.astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_block, D), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), jnp.arange(n_win))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # (B,Hkv,G,Cq,D) -> (B,Cq,Hkv,G,D)
        return None, out.transpose(0, 3, 1, 2, 4).astype(q.dtype)

    qblocks = qg.transpose(1, 0, 2, 3, 4, 5)  # (nq,B,Cq,Hkv,G,D)
    _, outs = lax.scan(q_step, None, (qblocks, jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, T, H, D)
    return out


def flash_attention_xla_triangular(q, k, v, *, q_offset=0, block=1024):
    """Causal blockwise attention with a BALANCED TRIANGULAR schedule.

    The plain blockwise path visits the full (nq x nkv) rectangle and masks
    the upper triangle -- half the attention FLOPs are dead.  Pairing query
    row p with row nq-1-p gives every pair the same fixed budget of nq+1 kv
    steps (p+1 for the early row + nq-p for the late row), so a scan over
    nq/2 pairs x (nq+1) steps covers exactly the causal triangle:
    ~2x fewer attention FLOPs at 32k prefill (EXPERIMENTS.md SSPerf).
    Requires T == S, T % block == 0, nq even; callers fall back otherwise.
    """
    B, T, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    assert T == S and T % block == 0 and (T // block) % 2 == 0
    nq = T // block
    scale = 1.0 / math.sqrt(D)

    qg = q.reshape(B, nq, block, Hkv, G, D).transpose(1, 0, 2, 3, 4, 5)

    def pair_step(_, p):
        qa = jax.lax.dynamic_index_in_dim(qg, p, 0, keepdims=False)
        qb = jax.lax.dynamic_index_in_dim(qg, nq - 1 - p, 0, keepdims=False)
        pos_a = p * block + jnp.arange(block) + q_offset
        pos_b = (nq - 1 - p) * block + jnp.arange(block) + q_offset

        def kv_step(carry, jj):
            ma, la, acca, mb, lb, accb = carry
            take_a = jj <= p
            kv_idx = jnp.where(take_a, jj, jj - p - 1)
            kblk = lax.dynamic_slice_in_dim(k, kv_idx * block, block, 1)
            vblk = lax.dynamic_slice_in_dim(v, kv_idx * block, block, 1)
            kpos = kv_idx * block + jnp.arange(block)
            qsel = jnp.where(take_a, qa, qb)
            qpos = jnp.where(take_a, pos_a, pos_b)
            s = jnp.einsum("bthgd,bshd->bhgts", qsel, kblk,
                           preferred_element_type=jnp.float32) * scale
            msk = kpos[None, :] <= qpos[:, None]
            s = jnp.where(msk[None, None, None], s, -1e30)
            m_old = jnp.where(take_a, ma, mb)
            l_old = jnp.where(take_a, la, lb)
            acc_old = jnp.where(take_a, acca, accb)
            m_new = jnp.maximum(m_old, s.max(axis=-1))
            pexp = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_old - m_new)
            l_new = l_old * corr + pexp.sum(axis=-1)
            pv = jnp.einsum("bhgts,bshd->bhgtd", pexp.astype(q.dtype), vblk)
            acc_new = acc_old * corr[..., None].astype(acc_old.dtype) + \
                pv.astype(jnp.float32)
            ma = jnp.where(take_a, m_new, ma)
            la = jnp.where(take_a, l_new, la)
            acca = jnp.where(take_a, acc_new, acca)
            mb = jnp.where(take_a, mb, m_new)
            lb = jnp.where(take_a, lb, l_new)
            accb = jnp.where(take_a, accb, acc_new)
            return (ma, la, acca, mb, lb, accb), None

        z = lambda *s_: jnp.zeros(s_, jnp.float32)
        m0 = jnp.full((B, Hkv, G, block), -jnp.inf, jnp.float32)
        carry0 = (m0, z(B, Hkv, G, block), z(B, Hkv, G, block, D),
                  m0, z(B, Hkv, G, block), z(B, Hkv, G, block, D))
        (ma, la, acca, mb, lb, accb), _ = lax.scan(
            kv_step, carry0, jnp.arange(nq + 1))
        outa = (acca / jnp.maximum(la[..., None], 1e-30))
        outb = (accb / jnp.maximum(lb[..., None], 1e-30))
        # (B,Hkv,G,block,D) -> (B,block,Hkv,G,D)
        f = lambda o: o.transpose(0, 3, 1, 2, 4).astype(q.dtype)
        return None, (f(outa), f(outb))

    _, (outs_a, outs_b) = lax.scan(pair_step, None, jnp.arange(nq // 2))
    # outs_a rows: p = 0..nq/2-1; outs_b rows: nq-1-p (descending)
    out = jnp.concatenate([outs_a, outs_b[::-1]], axis=0)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, T, H, D)
    return out


def decode_attention(q, k_cache, v_cache, cache_len, *, window=0):
    """Single-step decode: q (B,1,H,D) over cache (B,S,Hkv,D); positions
    >= cache_len are masked.  `window` additionally masks stale entries
    (the SWA ring buffer keeps only `window` positions so S == window)."""
    B, _, H, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D)
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(S)
    valid = kpos[None, :] < cache_len[:, None]  # (B,S)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache)
    return out.reshape(B, 1, H, D)


def ring_decode_attention(q, k_cache, v_cache, cache_len, *, segments):
    """Seq-sharded (ring) decode: identical math to decode_attention,
    restructured so the seq dim splits into `segments` independent
    slices merged by log-sum-exp.

    Under SPMD with the cache's seq dim sharded over "model" (the
    CacheSpec "ring" layout), each shard computes partial attention over
    its OWN S/n cache slice; the cross-shard traffic is the per-segment
    (B, n, Hkv, G) max/sum statistics plus the (B, Hkv, G, D) partial
    outputs -- instead of GSPMD all-gathering the whole cache to every
    model shard (the measured 68 GB/step failure mode this layout
    replaces).  Numerics: scores and softmax statistics in fp32 with ONE
    global max (exp(s - M) == what jax.nn.softmax computes), so the
    probabilities match decode_attention's bit-for-bit up to fp32
    summation order; greedy decode is token-identical on the parity
    suite (tests/test_cache_spec.py).
    """
    B, _, H, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    n = segments
    Sn = S // n
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D)
    seg_ax = ("batch", ("model",), None, "kv_heads", None)
    ks = constrain(k_cache.reshape(B, n, Sn, Hkv, D), seg_ax)
    vs = constrain(v_cache.reshape(B, n, Sn, Hkv, D), seg_ax)
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bhgd,bnshd->bnhgs", qg, ks,
                   preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(n)[:, None] * Sn + jnp.arange(Sn)[None, :]   # (n,Sn)
    valid = kpos[None] < cache_len[:, None, None]                  # (B,n,Sn)
    s = jnp.where(valid[:, :, None, None, :], s, -1e30)
    m_seg = s.max(axis=-1)                     # (B,n,Hkv,G) segment-local
    M = m_seg.max(axis=1, keepdims=True)       # cross-segment (tiny)
    p = jnp.exp(s - M[..., None])
    l = p.sum(axis=-1).sum(axis=1)             # (B,Hkv,G) cross-segment
    probs = (p / l[:, None, :, :, None]).astype(q.dtype)
    out = jnp.einsum("bnhgs,bnshd->bhgd", probs, vs)
    return out.reshape(B, 1, H, D)


def paged_kv_write(kp, vp, bt, kk, vv, positions):
    """Scatter per-token K/V into the paged pool.

    kp/vp: (NB, BS, Hkv, D) block pool shared by ALL sequences;
    bt: (B, nbmax) block tables; kk/vv: (B, C, Hkv, D) new K/V;
    positions: (B, C) ABSOLUTE positions, -1 marking padding rows whose
    writes are dropped (bucketed prefill pads the tail chunk).

    Distinct sequences write distinct blocks by construction (shared
    prefix blocks are read-only: the allocator only shares full prompt
    blocks, and writes happen at positions >= the private tail), so the
    scatter is collision-free.
    """
    nb, bs = kp.shape[0], kp.shape[1]
    valid = positions >= 0
    pos = jnp.where(valid, positions, 0)
    page = jnp.take_along_axis(bt, pos // bs, axis=1)          # (B, C)
    flat = jnp.where(valid, page * bs + pos % bs, nb * bs)     # OOB drops
    flat = flat.reshape(-1)
    kf = kp.reshape(nb * bs, *kp.shape[2:])
    vf = vp.reshape(nb * bs, *vp.shape[2:])
    kf = kf.at[flat].set(
        kk.reshape(-1, *kk.shape[2:]).astype(kp.dtype), mode="drop")
    vf = vf.at[flat].set(
        vv.reshape(-1, *vv.shape[2:]).astype(vp.dtype), mode="drop")
    return kf.reshape(kp.shape), vf.reshape(vp.shape)


def paged_gather_kv(kp, vp, bt):
    """Gather each sequence's K/V view from the block pool.

    Returns (B, nbmax*BS, Hkv, D) -- unallocated table entries (0-filled)
    gather block 0's contents; callers mask by sequence length so the
    garbage never contributes attention weight.
    """
    nb, bs = kp.shape[0], kp.shape[1]
    B, nbmax = bt.shape
    idx = (bt[:, :, None] * bs + jnp.arange(bs)[None, None]).reshape(B, -1)
    kf = kp.reshape(nb * bs, *kp.shape[2:])
    vf = vp.reshape(nb * bs, *vp.shape[2:])
    return kf[idx], vf[idx]


def paged_chunk_attention(q, k_seq, v_seq, positions):
    """Exact causal attention of a prefill CHUNK over the paged view.

    q: (B, C, H, D) chunk queries; k_seq/v_seq: (B, S, Hkv, D) gathered
    pages (already containing this chunk's K/V *and* any shared-prefix
    blocks); positions: (B, C) absolute query positions (-1 = padding;
    such rows attend to nothing real and their output is discarded).
    Scores materialise as (C, S) only -- long prompts stream through in
    bounded-size chunks.
    """
    B, C, H, D = q.shape
    S, Hkv = k_seq.shape[1], k_seq.shape[2]
    G = H // Hkv
    qg = q.reshape(B, C, Hkv, G, D)
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bthgd,bshd->bhgts", qg, k_seq,
                   preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(S)
    mask = kpos[None, None, :] <= positions[:, :, None]        # (B, C, S)
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgts,bshd->bthgd", p, v_seq)
    return out.reshape(B, C, H, D)


# the paged-cache convention lives in models/cache.py with the rest
paged_attention_cache_defs = kvcache.paged_attention_cache_defs


def select_attention(q, k, v, *, causal=True, window=0, q_offset=0):
    """Pick exact vs blockwise path from the (static) sequence length.

    Threshold 4096: a lower threshold was tried and REFUTED -- the XLA
    blockwise path's scan carries round-trip HBM every kv step, so its
    measured traffic is HIGHER than materialising (T,S) scores at 4k; true
    flash locality needs the fused Pallas kernel (kernels/flash_attention,
    TPU path).  Blockwise remains required above 4k where (T,S) scores
    would not fit at all (EXPERIMENTS.md SSPerf, mixtral iteration 2)."""
    T, S = q.shape[1], k.shape[1]
    if max(T, S) <= 4096:
        return attention_full(q, k, v, causal=causal, window=window,
                              q_offset=q_offset)
    if (causal and not window and T == S and T % 1024 == 0
            and (T // 1024) % 2 == 0):
        # long causal prefill: triangular schedule halves attention FLOPs
        return flash_attention_xla_triangular(q, k, v, q_offset=q_offset)
    return flash_attention_xla(q, k, v, causal=causal, window=window,
                               q_offset=q_offset)


# --------------------------------------------------------------------------
# Attention block (params + apply, train/prefill/decode)
# --------------------------------------------------------------------------

def attention_defs(cfg, d_model=None, cross=False):
    d = d_model or cfg.d_model
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    defs = {
        "wq": pdef((d, H, Dh), ("embed", "heads", None), fan_in_axes=(0,)),
        "wk": pdef((d, Hkv, Dh), ("embed", "kv_heads", None), fan_in_axes=(0,)),
        "wv": pdef((d, Hkv, Dh), ("embed", "kv_heads", None), fan_in_axes=(0,)),
        "wo": pdef((H, Dh, d), ("heads", None, "embed_tp"), fan_in_axes=(0, 1)),
    }
    if cfg.qkv_bias:
        defs["bq"] = pdef((H, Dh), ("heads", None), init="zeros")
        defs["bk"] = pdef((Hkv, Dh), ("kv_heads", None), init="zeros")
        defs["bv"] = pdef((Hkv, Dh), ("kv_heads", None), init="zeros")
    return defs


def attention_apply(p, cfg, x, positions, *, mode="train", cache=None,
                    kv_source=None, causal=True, window=None,
                    is_cross=False):
    """mode: train/prefill (full seq) or decode (T==1, uses cache).

    Cross-attention (enc-dec): pass kv_source=enc_out in train/prefill, or
    is_cross=True in decode (cache then holds the STATIC encoder K/V built
    at prefill -- never updated, no RoPE).  Returns (out, new_cache).
    """
    is_cross = is_cross or kv_source is not None
    window = cfg.window if window is None else window
    B, T, _ = x.shape
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    # When heads don't divide the TP axis (e.g. 20H on a 16-way model axis)
    # head-sharding is impossible and attention would run fully REPLICATED
    # on every model shard.  Fall back to sequence/context parallelism: the
    # q blocks shard over "model", k/v stay full, and the output re-gathers.
    m = mesh_axis_size("model")
    seq_cp = (cfg.num_heads % m != 0 and T % m == 0 and T > 1
              and not is_cross)
    q_axes = ("batch", ("model",), "heads", None) if seq_cp else \
        ("batch", None, "heads", None)
    q = constrain(q, q_axes)
    if not is_cross:
        q = rope_apply(q, positions, cfg.rope_theta, cfg.rope_fraction)

    if is_cross and mode == "decode":
        # static encoder K/V cache: read-only attention over enc_len
        out = decode_attention(q, cache["k"], cache["v"], cache["len"])
        out = constrain(out, ("batch", None, "heads", None))
        y = jnp.einsum("bthk,hkd->btd", out, p["wo"])
        return constrain(y, ("batch", None, None)), cache

    xs = kv_source if kv_source is not None else x
    kk = jnp.einsum("bsd,dhk->bshk", xs, p["wk"])
    vv = jnp.einsum("bsd,dhk->bshk", xs, p["wv"])
    if "bk" in p:
        kk = kk + p["bk"]
        vv = vv + p["bv"]
    if not is_cross:
        kk = rope_apply(kk, positions, cfg.rope_theta, cfg.rope_fraction)

    new_cache = cache
    if mode == "chunk_prefill" and cache is not None and "kp" in cache:
        # paged chunked prefill: scatter this chunk's K/V into the block
        # pool, then exact attention over the sequence's gathered view
        # (which already holds any shared-prefix blocks -- their
        # positions are simply never re-computed).
        assert not window, "paged cache does not support sliding windows"
        kp, vp = paged_kv_write(cache["kp"], cache["vp"], cache["bt"],
                                kk, vv, positions)
        k_seq, v_seq = paged_gather_kv(kp, vp, cache["bt"])
        out = paged_chunk_attention(q, k_seq, v_seq, positions)
        new_cache = {"kp": kp, "vp": vp}
    elif mode == "chunk_prefill":
        # CONTIGUOUS chunked prefill (rectangular batch: all rows at the
        # same offset): write this chunk's K/V into the spec'd cache at
        # the current length, then blockwise attention of the chunk over
        # the cache prefix.  Streams a long prompt through in bounded
        # chunks so the per-step temporaries scale with the chunk, while
        # the resident cache keeps the spec's (ring / int8) footprint --
        # the prefill path the layout policy probes for cells whose
        # one-shot prefill blows the HBM budget.
        spec = kvcache.spec_of(cfg)
        cache_len = cache["len"]
        new_cache = kvcache.write_kv(cache, kk, vv,
                                     cache_len.astype(jnp.int32), spec=spec)
        new_cache["len"] = cache_len + T
        k_read, v_read = kvcache.read_kv(new_cache)
        out = select_attention(q, k_read, v_read, causal=True,
                               window=window, q_offset=cache_len[0])
    elif mode == "decode" and "kp" in cache:
        assert not window, "paged cache does not support sliding windows"
        kp, vp, bt = cache["kp"], cache["vp"], cache["bt"]
        cache_len = cache["len"]
        kp, vp = paged_kv_write(kp, vp, bt, kk, vv, cache_len[:, None])
        k_seq, v_seq = paged_gather_kv(kp, vp, bt)
        out = decode_attention(q, k_seq, v_seq, cache_len + 1)
        new_cache = {"kp": kp, "vp": vp, "bt": bt, "len": cache_len + 1}
    elif mode == "decode":
        spec = kvcache.spec_of(cfg)
        cache_len = cache["len"]
        S = cache["k"].shape[1]
        if window and S == window:
            slots = (cache_len % window).astype(jnp.int32)  # ring buffer
        else:
            slots = cache_len.astype(jnp.int32)
        # PER-BATCH slot writes (vmapped DUS inside cache.write_kv):
        # sequences at different positions coexist in one batch
        # (continuous batching, serve_loop); int8 caches quantise the new
        # row and update the rowwise scales alongside.
        new_cache = kvcache.write_kv(cache, kk, vv, slots, spec=spec)
        new_cache["len"] = cache_len + 1
        k_read, v_read = kvcache.read_kv(new_cache)
        # SWA ring buffers (S == window) keep their wraparound masking in
        # decode_attention's window arg; segment the seq dim otherwise.
        n = kvcache.ring_segments(spec, S) if not window else 1
        if n > 1:
            out = ring_decode_attention(q, k_read, v_read, cache_len + 1,
                                        segments=n)
        else:
            out = decode_attention(q, k_read, v_read, cache_len + 1,
                                   window=window)
    else:
        out = select_attention(q, kk, vv, causal=causal and kv_source is None,
                               window=window)
        if mode == "prefill" and kv_source is None:
            new_cache = kvcache.pack_prefill_cache(
                cfg, kk, vv, window=window)
    out = constrain(out, q_axes if seq_cp else ("batch", None, "heads", None))
    y = jnp.einsum("bthk,hkd->btd", out, p["wo"])
    return constrain(y, ("batch", None, None)), new_cache


# the contiguous-cache convention (shapes / dtypes / logical axes per
# CacheSpec) lives in models/cache.py
attention_cache_defs = kvcache.attention_cache_defs


# --------------------------------------------------------------------------
# Dense MLP (gated or plain)
# --------------------------------------------------------------------------

def mlp_defs(cfg):
    gated = cfg.act in ("silu", "gelu")
    d, f = cfg.d_model, cfg.d_ff
    defs = {
        "w_up": pdef((d, f), ("embed", "ffn"), fan_in_axes=(0,)),
        "w_down": pdef((f, d), ("ffn", "embed_tp"), fan_in_axes=(0,)),
    }
    if gated:
        defs["w_gate"] = pdef((d, f), ("embed", "ffn"), fan_in_axes=(0,))
    return defs


def mlp_apply(p, cfg, x):
    h = jnp.einsum("btd,df->btf", x, p["w_up"])
    if "w_gate" in p:
        g = jnp.einsum("btd,df->btf", x, p["w_gate"])
        h = act_fn(cfg.act)(g) * h
    else:
        h = act_fn(cfg.act)(h)
    h = constrain(h, ("batch", None, "ffn"))
    return jnp.einsum("btf,fd->btd", h, p["w_down"])


# --------------------------------------------------------------------------
# MoE (gather-based dispatch: no (T,E,C) one-hot einsum FLOPs)
# --------------------------------------------------------------------------

def moe_defs(cfg):
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    return {
        "w_router": pdef((d, E), ("embed", None), dtype=jnp.float32,
                         fan_in_axes=(0,)),
        "w_gate": pdef((E, d, f), ("experts", "embed", "expert_ffn"),
                       fan_in_axes=(1,)),
        "w_up": pdef((E, d, f), ("experts", "embed", "expert_ffn"),
                     fan_in_axes=(1,)),
        "w_down": pdef((E, f, d), ("experts", "expert_ffn", "embed"),
                       fan_in_axes=(1,)),
    }


def moe_capacity(cfg, tokens: int) -> int:
    # capacity_factor <= 0 means DROPLESS: an expert can receive at most one
    # choice per token, so capacity == tokens guarantees no token ever
    # overflows (smoke configs use this -- an untrained router is imbalanced
    # enough to overflow any reasonable factor at test scale).
    if cfg.capacity_factor <= 0:
        return tokens
    c = int(math.ceil(tokens * cfg.experts_per_token / cfg.num_experts
                      * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to 8


def _moe_groups(B: int, T: int, min_tokens: int = 2048) -> int:
    """Largest divisor of B keeping >= min_tokens tokens per group.

    The GROUP dimension is the key to sharded dispatch: routing/capacity is
    computed per group and groups shard over the data axis, so the expert
    einsums are (G, E, C_g, d) with G sharded -- WITHOUT it, the (E, C)
    dispatch is global and GSPMD replicates the whole expert computation on
    every data shard (measured 16x FLOP blowup; EXPERIMENTS.md SSPerf)."""
    g = B
    while g > 1 and (B * T) // g < min_tokens:
        g //= 2
    while B % g != 0:
        g -= 1
    return max(g, 1)


def moe_apply(p, cfg, x):
    """Top-k routed expert MLP with per-group capacity + token dropping.

    Dispatch/combine are GATHERS (memory movement), not one-hot einsums, so
    HLO FLOPs stay proportional to active-expert compute.
    """
    B, T, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    n = B * T
    G = _moe_groups(B, T)
    ng = n // G
    C = moe_capacity(cfg, ng)
    xg = x.reshape(G, ng, d)

    logits = jnp.einsum("gnd,de->gne", xg.astype(jnp.float32), p["w_router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gval, gidx = lax.top_k(probs, k)                     # (G,ng,k)
    gval = gval / jnp.maximum(gval.sum(-1, keepdims=True), 1e-9)

    # Position of each (token, choice) within its expert, per group:
    # slot-major cumsum so choice 0 of token t beats choice 1 of token t.
    onehot = jax.nn.one_hot(gidx, E, dtype=jnp.int32)    # (G,ng,k,E)
    flat = onehot.transpose(0, 2, 1, 3).reshape(G, k * ng, E)
    pos_flat = jnp.cumsum(flat, axis=1) - flat
    pos = (pos_flat.reshape(G, k, ng, E).transpose(0, 2, 1, 3)
           * onehot).sum(-1)                             # (G,ng,k)
    keep = pos < C

    # slot_token[g, e, c] = source token index within group (ng == padding)
    gg = jnp.arange(G, dtype=jnp.int32)[:, None]
    e_flat = jnp.where(keep, gidx, E).reshape(G, -1)
    c_flat = jnp.where(keep, pos, 0).reshape(G, -1)
    tok = jnp.broadcast_to(jnp.arange(ng, dtype=jnp.int32)[None, :, None],
                           (G, ng, k)).reshape(G, -1)
    slot_token = jnp.full((G, E + 1, C), ng, jnp.int32)
    slot_token = slot_token.at[gg, e_flat, c_flat].set(tok, mode="drop")
    slot_token = slot_token[:, :E]

    x_pad = jnp.concatenate([xg, jnp.zeros((G, 1, d), xg.dtype)], axis=1)
    xe = jnp.take_along_axis(
        x_pad[:, :, None, :],                            # (G,ng+1,1,d)
        slot_token.reshape(G, -1)[:, :, None, None], axis=1
    ).reshape(G, E, C, d)                                # local gather per G
    xe = constrain(xe, ("batch", "experts", None, None))

    g_ = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    h = act_fn(cfg.act)(g_) * u
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])    # (G,E,C,d)
    ye = constrain(ye, ("batch", "experts", None, None))

    # combine: gather each token-choice's slot output, weight, sum over k
    ye_flat = jnp.concatenate(
        [ye.reshape(G, E * C, d), jnp.zeros((G, 1, d), ye.dtype)], axis=1)
    slot_id = jnp.where(keep, gidx * C + pos, E * C)     # (G,ng,k)
    yk = jnp.take_along_axis(
        ye_flat[:, :, None, :],
        slot_id.reshape(G, -1)[:, :, None, None], axis=1
    ).reshape(G, ng, k, d)
    y = jnp.einsum("gnkd,gnk->gnd", yk, gval.astype(yk.dtype) * keep)
    aux = _load_balance_loss(probs.reshape(n, E),
                             onehot.reshape(n, k, E), E, k)
    return y.reshape(B, T, d), aux


def _load_balance_loss(probs, onehot, E, k):
    """Switch-style auxiliary loss: E * sum(frac_tokens * frac_probs)."""
    frac_tokens = onehot.sum(axis=(0, 1)).astype(jnp.float32) / (
        probs.shape[0] * k)
    frac_probs = probs.mean(axis=0)
    return E * jnp.sum(frac_tokens * frac_probs)


# --------------------------------------------------------------------------
# Embedding / unembedding
# --------------------------------------------------------------------------

def embed_defs(cfg):
    # The INPUT table is sharded only on d_model (over data x model jointly):
    # a gather over a vocab-sharded table triggers SPMD "involuntary full
    # rematerialization" (replicates the gathered activations); the OUTPUT
    # projection contracts d_model, so vocab-sharding is fine there.
    defs = {"tok": pdef((cfg.vocab_size, cfg.d_model),
                        (None, ("data", "model")), init="embed")}
    if not cfg.tie_embeddings:
        defs["unembed"] = pdef((cfg.d_model, cfg.vocab_size),
                               ("embed", "vocab"), fan_in_axes=(0,))
    return defs


def embed_apply(p, tokens):
    return jnp.take(p["tok"], tokens, axis=0)


def unembed_apply(p, x):
    """Logits stay in activation dtype (bf16): with 150k+ vocabs an fp32
    (B,T,V) tensor would dominate memory; the loss reduces in fp32."""
    w = p.get("unembed")
    if w is None:
        w = p["tok"].T
    return jnp.einsum("btd,dv->btv", x, w)
