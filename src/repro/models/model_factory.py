"""Unified model interface: one `Model` object per architecture config.

Model exposes:
  param_defs()              -> pytree of ParamDef
  init(key)                 -> concrete params (CPU smoke / simulator tiers)
  apply(params, batch, mode, cache) -> (logits, aux_or_cache)
  cache_defs(batch, seq)    -> pytree of ParamDef for the decode KV/state cache
  input_defs(shape)         -> dict of ParamDef for every model input
  n_params / n_active_params -> ints (roofline MODEL_FLOPS terms)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.models import cnn, encdec, rglru, ssm, transformer
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.param import (abstract_params, count_params, init_params,
                                is_def, pdef)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    _defs: Callable
    _apply: Callable
    _cache_defs: Optional[Callable] = None

    # ---- params ----
    def param_defs(self):
        return self._defs(self.cfg)

    def abstract_params(self):
        return abstract_params(self.param_defs())

    def init(self, key):
        return init_params(key, self.param_defs())

    # ---- forward ----
    def apply(self, params, batch, *, mode="train", cache=None):
        return self._apply(params, self.cfg, batch, mode=mode, cache=cache)

    # ---- caches ----
    @property
    def supports_cache_spec(self) -> bool:
        """CacheSpec layouts (ring / int8) apply to growing KV caches;
        SSM / RG-LRU state and the enc-dec cross cache keep their own
        conventions."""
        return self.cfg.family in ("dense", "moe", "vlm")

    def cache_defs(self, batch: int, seq_len: int, spec=None):
        """Decode-cache defs; `spec` (a models/cache.CacheSpec or its
        string form) overrides the config's cache_spec for transformer
        families, letting the layout policy probe candidate specs
        without rebuilding the model."""
        if self._cache_defs is None:
            raise ValueError(f"{self.cfg.name}: no decode cache (family="
                             f"{self.cfg.family})")
        if spec is not None and self.supports_cache_spec:
            return self._cache_defs(self.cfg, batch, seq_len, spec=spec)
        return self._cache_defs(self.cfg, batch, seq_len)

    @property
    def supports_paged_cache(self) -> bool:
        """Block-table paging applies to growing KV caches (transformer
        families); SSM/RG-LRU state is O(1) per sequence and the enc-dec
        cross cache is static, so those keep the contiguous path."""
        return self.cfg.family in ("dense", "moe", "vlm")

    def paged_cache_defs(self, batch: int, num_blocks: int, block_size: int,
                         max_blocks_per_seq: int):
        if not self.supports_paged_cache:
            raise ValueError(f"{self.cfg.name}: paged KV cache unsupported "
                             f"(family={self.cfg.family})")
        return transformer.paged_cache_defs(
            self.cfg, batch, num_blocks, block_size, max_blocks_per_seq)

    # ---- inputs ----
    def input_defs(self, shape: ShapeConfig):
        cfg = self.cfg
        B = shape.global_batch
        if cfg.family == "cnn":
            return {
                "images": pdef((B, cfg.img_hw, cfg.img_hw, cfg.img_c),
                               ("batch", None, None, None), dtype=jnp.float32),
                "labels": pdef((B,), ("batch",), dtype=jnp.int32),
            }
        T = 1 if shape.kind == "decode" else shape.seq_len
        d: dict[str, Any] = {
            "tokens": pdef((B, T), ("batch", None), dtype=jnp.int32),
        }
        if shape.kind == "train":
            d["labels"] = pdef((B, T), ("batch", None), dtype=jnp.int32)
        if cfg.frontend == "vision_stub" and shape.kind != "decode":
            d["patch_embeds"] = pdef((B, cfg.frontend_len, cfg.d_model),
                                     ("batch", None, None),
                                     dtype=jnp.bfloat16)
        if cfg.is_encdec and shape.kind != "decode":
            el = encdec.enc_len_for(shape.seq_len)
            d["frames"] = pdef((B, el, cfg.d_model), ("batch", None, None),
                               dtype=jnp.bfloat16)
        if shape.kind == "decode":
            d["positions"] = pdef((B, 1), ("batch", None), dtype=jnp.int32)
        return d

    # ---- sizes (roofline) ----
    @property
    def n_params(self) -> int:
        return count_params(self.param_defs())

    @property
    def n_active_params(self) -> int:
        """Per-token active parameters (MoE: only k of E experts count)."""
        cfg = self.cfg
        if not cfg.num_experts:
            return self.n_params
        defs = self.param_defs()
        total = count_params(defs)
        moe = defs["layers"].get("moe")
        if moe is None:
            return total
        expert_leaves = [moe["w_gate"], moe["w_up"], moe["w_down"]]
        expert_total = sum(int(np.prod(l.shape)) for l in expert_leaves)
        active = expert_total * cfg.experts_per_token / cfg.num_experts
        return int(total - expert_total + active)


_FAMILY = {
    "dense": (transformer.lm_defs, transformer.lm_apply, transformer.cache_defs),
    "moe": (transformer.lm_defs, transformer.lm_apply, transformer.cache_defs),
    "vlm": (transformer.lm_defs, transformer.lm_apply, transformer.cache_defs),
    "ssm": (ssm.ssm_lm_defs, ssm.ssm_lm_apply, ssm.ssm_cache_defs),
    "hybrid": (rglru.hybrid_lm_defs, rglru.hybrid_lm_apply,
               rglru.hybrid_cache_defs),
    "audio": (encdec.encdec_defs, encdec.encdec_apply, encdec.encdec_cache_defs),
    "cnn": (cnn.cnn_defs, cnn.cnn_apply, None),
    "mlp": (cnn.mlp_classifier_defs, cnn.mlp_classifier_apply, None),
}


def build_model(cfg: ModelConfig) -> Model:
    fam = "mlp" if (cfg.family == "cnn" and not cfg.cnn_channels
                    and cfg.d_model) else cfg.family
    defs, apply, cache = _FAMILY[fam]
    return Model(cfg, defs, apply, cache)
