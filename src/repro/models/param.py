"""Parameter-definition pytrees.

A model is described by a pytree of ParamDef leaves (shape + dtype + logical
axes + initializer).  From one definition tree we derive:
  * abstract params  (ShapeDtypeStruct, for the AOT dry-run -- no allocation)
  * concrete params  (for CPU smoke tests / the FL simulator)
  * PartitionSpecs   (via dist.sharding rules, for pjit in/out shardings)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    dtype: jnp.dtype
    logical_axes: tuple
    init: str = "normal"   # "normal" | "zeros" | "ones" | "embed" | "scalar:<v>"
    fan_in_axes: tuple[int, ...] = ()  # dims contributing to fan-in for scaling

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def pdef(shape: Sequence[int], axes: Sequence, dtype=jnp.bfloat16,
         init: str = "normal", fan_in_axes: Sequence[int] = ()) -> ParamDef:
    return ParamDef(tuple(int(s) for s in shape), jnp.dtype(dtype), tuple(axes),
                    init, tuple(fan_in_axes))


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def abstract_params(defs):
    return jax.tree.map(lambda d: d.abstract(), defs, is_leaf=is_def)


def init_leaf(key, d: ParamDef):
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init.startswith("scalar:"):
        v = float(d.init.split(":")[1])
        return jnp.full(d.shape, v, d.dtype)
    if d.init == "embed":
        scale = 1.0
    else:
        fan_in = 1
        for ax in (d.fan_in_axes or range(max(len(d.shape) - 1, 1))):
            fan_in *= d.shape[ax] if ax < len(d.shape) else 1
        scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, d.shape, jnp.float32) * scale).astype(d.dtype)


def init_params(key, defs):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    vals = [init_leaf(k, d) for k, d in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def stack_defs(defs, n: int):
    """Prepend a stacked `layers` axis of size n to every leaf (for scan)."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, d.dtype, ("layers",) + d.logical_axes,
                           d.init, tuple(a + 1 for a in d.fan_in_axes)),
        defs, is_leaf=is_def)


def count_params(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return int(sum(int(np.prod(l.shape)) for l in leaves))


def param_bytes(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return int(sum(int(np.prod(l.shape)) * l.dtype.itemsize for l in leaves))
