"""Griffin-style hybrid (recurrentgemma-9b): RG-LRU recurrent blocks + local
sliding-window attention in a 2:1 pattern, each followed by a gated MLP.

38 layers = 12 super-blocks of [rec, rec, attn] (scanned) + a tail of
[rec, rec].  The RG-LRU diagonal recurrence reuses the same chunked linear
scan as the SSM module (and the `linrec` Pallas kernel on TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.sharding import constrain
from repro.models import layers as L
from repro.models.param import pdef, stack_defs
from repro.models.ssm import _causal_conv, _chunked_linear_scan

_C_RGLRU = 8.0


def rglru_defs(cfg):
    d, r = cfg.d_model, cfg.lru_width
    return {
        "w_x": pdef((d, r), ("embed", "lru_width"), fan_in_axes=(0,)),
        "w_y": pdef((d, r), ("embed", "lru_width"), fan_in_axes=(0,)),
        "conv_w": pdef((cfg.conv_width, r), (None, "lru_width")),
        "conv_b": pdef((r,), ("lru_width",), init="zeros"),
        "w_rgate": pdef((r, r), ("lru_width", None), fan_in_axes=(0,)),
        "b_rgate": pdef((r,), (None,), init="zeros"),
        "w_igate": pdef((r, r), ("lru_width", None), fan_in_axes=(0,)),
        "b_igate": pdef((r,), (None,), init="zeros"),
        "lam": pdef((r,), (None,), dtype=jnp.float32, init="scalar:-1.0"),
        "w_out": pdef((r, d), ("lru_width", "embed_tp"), fan_in_axes=(0,)),
    }


def rglru_apply(p, cfg, x, *, mode="train", cache=None):
    """Griffin recurrent block. x: (B,T,d) -> (out, new_cache)."""
    B, T, _ = x.shape
    w = cfg.conv_width
    xb = jnp.einsum("btd,dr->btr", x, p["w_x"])
    yb = jnp.einsum("btd,dr->btr", x, p["w_y"])
    xb = constrain(xb, ("batch", None, "lru_width"))

    if mode == "decode":
        win = jnp.concatenate([cache["conv"], xb], axis=1)   # (B,w,r)
        xc = jnp.einsum("bwr,wr->br", win.astype(jnp.float32),
                        p["conv_w"].astype(jnp.float32))
        xc = (xc + p["conv_b"].astype(jnp.float32)).astype(x.dtype)[:, None]
        conv_new = win[:, 1:]
    else:
        xc = _causal_conv(xb, p["conv_w"], p["conv_b"])
        conv_new = xb[:, -(w - 1):]

    rg = jax.nn.sigmoid(
        (jnp.einsum("btr,rs->bts", xc, p["w_rgate"])
         + p["b_rgate"]).astype(jnp.float32))
    ig = jax.nn.sigmoid(
        (jnp.einsum("btr,rs->bts", xc, p["w_igate"])
         + p["b_igate"]).astype(jnp.float32))
    log_a = -_C_RGLRU * jax.nn.softplus(p["lam"].astype(jnp.float32)) * rg
    a = jnp.exp(log_a)                                       # (B,T,r)
    gated_x = ig * xc.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-9)) * gated_x

    h0 = cache["h"] if mode == "decode" else jnp.zeros(
        (B, cfg.lru_width), jnp.float32)
    hs, hT = _chunked_linear_scan(a, b, h0, chunk=256)

    y = (hs.astype(x.dtype)) * jax.nn.gelu(yb)
    out = jnp.einsum("btr,rd->btd", y, p["w_out"])
    out = constrain(out, ("batch", None, None))

    new_cache = None
    if mode == "decode":
        new_cache = {"conv": conv_new, "h": hT, "len": cache["len"] + 1}
    elif mode == "prefill":
        new_cache = {"conv": conv_new, "h": hT,
                     "len": jnp.full((B,), T, jnp.int32)}
    return out, new_cache


def _residual_pair_defs(cfg, mixer: str):
    d = {"ln1": L.norm_defs(cfg), "ln2": L.norm_defs(cfg),
         "mlp": L.mlp_defs(cfg)}
    d["mix"] = rglru_defs(cfg) if mixer == "rec" else L.attention_defs(cfg)
    return d


def _pair_apply(p, cfg, x, positions, mixer, mode, cache):
    h = L.apply_norm(p["ln1"], x, cfg.norm)
    if mixer == "rec":
        a, new_cache = rglru_apply(p["mix"], cfg, h, mode=mode, cache=cache)
    else:
        a, new_cache = L.attention_apply(p["mix"], cfg, h, positions,
                                         mode=mode, cache=cache,
                                         window=cfg.window)
    x = x + a
    h = L.apply_norm(p["ln2"], x, cfg.norm)
    return x + L.mlp_apply(p["mlp"], cfg, h), new_cache


def _superblock_defs(cfg):
    return {
        "rec0": _residual_pair_defs(cfg, "rec"),
        "rec1": _residual_pair_defs(cfg, "rec"),
        "attn": _residual_pair_defs(cfg, "attn"),
    }


def hybrid_counts(cfg):
    n_super = cfg.num_layers // 3
    n_tail = cfg.num_layers - 3 * n_super  # leftover rec layers (0..2)
    return n_super, n_tail


def hybrid_lm_defs(cfg):
    n_super, n_tail = hybrid_counts(cfg)
    defs = {
        "embed": L.embed_defs(cfg),
        "super": stack_defs(_superblock_defs(cfg), n_super),
        "final_norm": L.norm_defs(cfg),
    }
    for i in range(n_tail):
        defs[f"tail{i}"] = _residual_pair_defs(cfg, "rec")
    return defs


def _rec_cache_defs(cfg, batch):
    return {
        "conv": pdef((batch, cfg.conv_width - 1, cfg.lru_width),
                     ("batch", None, "lru_width"), init="zeros"),
        "h": pdef((batch, cfg.lru_width), ("batch", "lru_width"),
                  dtype=jnp.float32, init="zeros"),
        "len": pdef((batch,), ("batch",), dtype=jnp.int32, init="zeros"),
    }


def hybrid_cache_defs(cfg, batch: int, seq_len: int):
    n_super, n_tail = hybrid_counts(cfg)
    per_super = {
        "rec0": _rec_cache_defs(cfg, batch),
        "rec1": _rec_cache_defs(cfg, batch),
        "attn": L.attention_cache_defs(cfg, batch, seq_len),
    }
    defs = {"super": stack_defs(per_super, n_super)}
    for i in range(n_tail):
        defs[f"tail{i}"] = _rec_cache_defs(cfg, batch)
    return defs


def hybrid_lm_apply(params, cfg, batch_inputs, *, mode="train", cache=None):
    tokens = batch_inputs["tokens"]
    x = L.embed_apply(params["embed"], tokens)
    x = constrain(x, ("batch", None, None))
    B, T = x.shape[0], x.shape[1]
    n_super, n_tail = hybrid_counts(cfg)

    if mode == "decode":
        positions = batch_inputs.get(
            "positions", cache["super"]["rec0"]["len"][0].reshape(B, 1))
    else:
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))

    def body(carry, xs):
        x = carry
        lp, lc = xs if mode == "decode" else (xs, None)
        ncache = {}
        x, ncache["rec0"] = _pair_apply(lp["rec0"], cfg, x, positions, "rec",
                                        mode, lc["rec0"] if lc else None)
        x, ncache["rec1"] = _pair_apply(lp["rec1"], cfg, x, positions, "rec",
                                        mode, lc["rec1"] if lc else None)
        x, ncache["attn"] = _pair_apply(lp["attn"], cfg, x, positions, "attn",
                                        mode, lc["attn"] if lc else None)
        return x, (ncache if mode != "train" else None)

    if cfg.remat and mode == "train":
        body = jax.checkpoint(body, prevent_cse=False)

    xs = (params["super"], cache["super"]) if mode == "decode" \
        else params["super"]
    x, super_cache = lax.scan(body, x, xs)

    new_cache = {"super": super_cache} if mode != "train" else None
    for i in range(n_tail):
        tc = cache[f"tail{i}"] if mode == "decode" else None
        x, nc = _pair_apply(params[f"tail{i}"], cfg, x, positions, "rec",
                            mode, tc)
        if mode != "train":
            new_cache[f"tail{i}"] = nc

    if mode == "prefill":
        x = x[:, -1:]  # serving needs only the last position's logits
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed_apply(params["embed"], x)
    logits = constrain(logits, ("batch", None, "vocab"))
    if mode == "train":
        return logits, 0.0
    return logits, new_cache
