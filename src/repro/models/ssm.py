"""Mamba-1 style selective SSM (falcon-mamba-7b backbone).

The selective scan is a diagonal first-order linear recurrence
    h_t = a_t * h_{t-1} + b_t,     a_t = exp(dt_t * A),  b_t = dt_t B_t x_t
evaluated with a CHUNKED scan: an outer `lax.scan` over sequence chunks
(carrying h) and an inner `associative_scan` within the chunk, so the
(B, T, d_inner, N) state trajectory is never materialised for the full
sequence -- the pure-XLA analogue of the fused CUDA selective-scan, and the
same blocking the Pallas `linrec` kernel uses on TPU.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.sharding import constrain
from repro.models import layers as L
from repro.models.param import pdef, stack_defs


def _dt_rank(cfg) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def mamba_defs(cfg):
    d, di, N, w = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.conv_width
    r = _dt_rank(cfg)
    return {
        "w_in": pdef((d, 2 * di), ("embed", "ssm_inner"), fan_in_axes=(0,)),
        "conv_w": pdef((w, di), (None, "ssm_inner")),
        "conv_b": pdef((di,), ("ssm_inner",), init="zeros"),
        "w_x": pdef((di, r + 2 * N), ("ssm_inner", None), fan_in_axes=(0,)),
        "w_dt": pdef((r, di), (None, "ssm_inner"), fan_in_axes=(0,)),
        "b_dt": pdef((di,), ("ssm_inner",), init="scalar:-4.6"),  # softplus->~0.01
        "a_log": pdef((di, N), ("ssm_inner", None), dtype=jnp.float32,
                      init="scalar:0.5"),
        "d_skip": pdef((di,), ("ssm_inner",), dtype=jnp.float32, init="ones"),
        "w_out": pdef((di, d), ("ssm_inner", "embed_tp"), fan_in_axes=(0,)),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv via shifts (GSPMD-friendly). x: (B,T,di)."""
    width = w.shape[0]
    y = jnp.zeros_like(x, shape=x.shape).astype(jnp.float32)
    for i in range(width):
        shifted = jnp.pad(x, ((0, 0), (width - 1 - i, 0), (0, 0)))[:, : x.shape[1]]
        y = y + shifted.astype(jnp.float32) * w[i].astype(jnp.float32)
    return (y + b.astype(jnp.float32)).astype(x.dtype)


def _chunked_linear_scan(a, b, h0, chunk):
    """h_t = a_t*h_{t-1} + b_t over axis 1. a,b: (B,T,...), h0: (B,...)."""
    B, T = a.shape[0], a.shape[1]
    chunk = min(chunk, T)
    assert T % chunk == 0
    nch = T // chunk
    ar = a.reshape((B, nch, chunk) + a.shape[2:]).swapaxes(0, 1)
    br = b.reshape((B, nch, chunk) + b.shape[2:]).swapaxes(0, 1)

    def combine(l, r):
        al, bl = l
        ar_, br_ = r
        return al * ar_, bl * ar_ + br_

    def chunk_step(h, ab):
        ac, bc = ab  # (B, chunk, ...)
        Acum, Bcum = lax.associative_scan(combine, (ac, bc), axis=1)
        hs = Acum * h[:, None] + Bcum        # (B, chunk, ...)
        return hs[:, -1], hs

    hT, ys = lax.scan(chunk_step, h0, (ar, br))
    ys = ys.swapaxes(0, 1).reshape((B, T) + a.shape[2:])
    return ys, hT


def _ssm_inner(p, cfg, xc, z, h0, *, chunk=256):
    """xc: conv+silu output (B,T,di); returns (y (B,T,d_inner), hT)."""
    N, r = cfg.ssm_state, _dt_rank(cfg)
    xdb = jnp.einsum("btd,dr->btr", xc, p["w_x"])
    _, _, C_ssm = jnp.split(xdb, [r, r + N], axis=-1)
    a, b = _ab(p, cfg, xc)                                     # (B,T,di,N)
    hs, hT = _chunked_linear_scan(a, b, h0, chunk)
    y = jnp.einsum("btdn,btn->btd", hs, C_ssm.astype(jnp.float32))
    y = y + p["d_skip"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(xc.dtype)
    return y, hT


def mamba_apply(p, cfg, x, *, mode="train", cache=None):
    """x: (B,T,d). Returns (out, new_cache)."""
    B, T, _ = x.shape
    di, N, w = cfg.d_inner, cfg.ssm_state, cfg.conv_width
    xz = jnp.einsum("btd,de->bte", x, p["w_in"])
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = constrain(xi, ("batch", None, "ssm_inner"))

    if mode == "decode":
        conv_st, h0 = cache["conv"], cache["h"]          # (B,w-1,di), (B,di,N)
        win = jnp.concatenate([conv_st, xi], axis=1)     # (B,w,di)
        xc = jnp.einsum("bwd,wd->bd", win.astype(jnp.float32),
                        p["conv_w"].astype(jnp.float32))
        xc = jax.nn.silu(xc + p["conv_b"].astype(jnp.float32))
        xc = xc.astype(x.dtype)[:, None]                 # (B,1,di)
        y, hT = _ssm_inner(p, cfg, xc, z, h0, chunk=1)
        new_cache = {"conv": win[:, 1:], "h": hT, "len": cache["len"] + 1}
    else:
        xc = jax.nn.silu(_causal_conv(xi, p["conv_w"], p["conv_b"])
                         .astype(jnp.float32)).astype(x.dtype)
        h0 = jnp.zeros((B, di, N), jnp.float32)
        y, hT = _ssm_inner(p, cfg, xc, z, h0)
        new_cache = None
        if mode == "prefill":
            new_cache = {
                "conv": xi[:, -(w - 1):],
                "h": hT,
                "len": jnp.full((B,), T, jnp.int32),
            }
    out = jnp.einsum("btd,de->bte", y, p["w_out"])
    return constrain(out, ("batch", None, None)), new_cache


def _ab(p, cfg, xc):
    N, r = cfg.ssm_state, _dt_rank(cfg)
    xdb = jnp.einsum("btd,dr->btr", xc, p["w_x"])
    dt_lowrank, B_ssm, _ = jnp.split(xdb, [r, r + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("btr,rd->btd", dt_lowrank, p["w_dt"]).astype(jnp.float32)
        + p["b_dt"].astype(jnp.float32))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    a = jnp.exp(dt[..., None] * A)
    b = (dt * xc.astype(jnp.float32))[..., None] * \
        B_ssm.astype(jnp.float32)[:, :, None, :]
    return a, b


def ssm_block_defs(cfg):
    return {"ln": L.norm_defs(cfg), "mamba": mamba_defs(cfg)}


def ssm_lm_defs(cfg):
    return {
        "embed": L.embed_defs(cfg),
        "layers": stack_defs(ssm_block_defs(cfg), cfg.num_layers),
        "final_norm": L.norm_defs(cfg),
    }


def ssm_cache_defs(cfg, batch: int, seq_len: int):
    per_layer = {
        "conv": pdef((batch, cfg.conv_width - 1, cfg.d_inner),
                     ("batch", None, "ssm_inner"), init="zeros"),
        "h": pdef((batch, cfg.d_inner, cfg.ssm_state),
                  ("batch", "ssm_inner", None), dtype=jnp.float32,
                  init="zeros"),
        "len": pdef((batch,), ("batch",), dtype=jnp.int32, init="zeros"),
    }
    return stack_defs(per_layer, cfg.num_layers)


def ssm_lm_apply(params, cfg, batch_inputs, *, mode="train", cache=None):
    tokens = batch_inputs["tokens"]
    x = L.embed_apply(params["embed"], tokens)
    x = constrain(x, ("batch", None, None))

    def body(carry, xs):
        x = carry
        lp, lc = xs if mode == "decode" else (xs, None)
        h = L.apply_norm(lp["ln"], x, cfg.norm)
        y, new_cache = mamba_apply(lp["mamba"], cfg, h, mode=mode, cache=lc)
        return x + y, new_cache

    if cfg.remat and mode == "train":
        body = jax.checkpoint(body, prevent_cse=False)

    xs = (params["layers"], cache) if mode == "decode" else params["layers"]
    x, new_cache = lax.scan(body, x, xs)
    if mode == "prefill":
        x = x[:, -1:]  # serving needs only the last position's logits
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed_apply(params["embed"], x)
    logits = constrain(logits, ("batch", None, "vocab"))
    if mode == "train":
        return logits, 0.0
    return logits, new_cache
