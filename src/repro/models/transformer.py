"""Decoder-only LM (dense + MoE + VLM-stub), scan-over-layers, 3 modes.

Modes:
  train   -- full-sequence forward, returns (logits, aux)
  prefill -- full-sequence forward, returns (logits, cache)
  decode  -- single-token step with KV cache, returns (logits, cache)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.sharding import constrain
from repro.models import layers as L
from repro.models.param import pdef, stack_defs, abstract_params


def block_defs(cfg):
    d = {
        "ln1": L.norm_defs(cfg),
        "attn": L.attention_defs(cfg),
        "ln2": L.norm_defs(cfg),
    }
    if cfg.family == "moe" or (cfg.num_experts and cfg.family != "dense"):
        d["moe"] = L.moe_defs(cfg)
    else:
        d["mlp"] = L.mlp_defs(cfg)
    return d


def lm_defs(cfg):
    return {
        "embed": L.embed_defs(cfg),
        "layers": stack_defs(block_defs(cfg), cfg.num_layers),
        "final_norm": L.norm_defs(cfg),
    }


def cache_defs(cfg, batch: int, seq_len: int, spec=None):
    """Decode-cache defs under a CacheSpec (default: cfg.cache_spec).
    The convention itself lives in models/cache.py."""
    per_layer = L.attention_cache_defs(cfg, batch, seq_len, spec)
    return stack_defs(per_layer, cfg.num_layers)


def paged_cache_defs(cfg, batch: int, num_blocks: int, block_size: int,
                     max_blocks_per_seq: int):
    """Block-table paged decode cache (see core/paging.py): one KV block
    pool per layer, shared by all slots, plus per-slot tables/lengths."""
    per_layer = L.paged_attention_cache_defs(
        cfg, batch, num_blocks, block_size, max_blocks_per_seq)
    return stack_defs(per_layer, cfg.num_layers)


def _block_apply(p, cfg, x, positions, mode, cache):
    h = L.apply_norm(p["ln1"], x, cfg.norm)
    a, new_cache = L.attention_apply(p["attn"], cfg, h, positions,
                                     mode=mode, cache=cache)
    x = x + a
    h = L.apply_norm(p["ln2"], x, cfg.norm)
    if "moe" in p:
        m, aux = L.moe_apply(p["moe"], cfg, h)
    else:
        m, aux = L.mlp_apply(p["mlp"], cfg, h), 0.0
    return x + m, new_cache, aux


def _embed_inputs(params, cfg, batch_inputs):
    """tokens (+ optional stub modality embeddings occupying a prefix)."""
    tokens = batch_inputs["tokens"]
    x = L.embed_apply(params["embed"], tokens)
    if cfg.frontend == "vision_stub" and "patch_embeds" in batch_inputs:
        pe = batch_inputs["patch_embeds"].astype(x.dtype)
        P = pe.shape[1]
        x = jnp.concatenate([pe, x[:, P:]], axis=1)
    return constrain(x, ("batch", None, None))


def lm_apply(params, cfg, batch_inputs, *, mode="train", cache=None):
    x = _embed_inputs(params, cfg, batch_inputs)
    B, T = x.shape[0], x.shape[1]
    if mode == "decode":
        # cache["len"] is stacked (L, B); all layers share the same length.
        positions = batch_inputs.get("positions", cache["len"][0].reshape(B, 1))
    elif mode == "chunk_prefill":
        # absolute positions of this chunk's tokens; -1 marks padding rows
        # (bucketed tail chunks) whose cache writes and logits are dropped.
        positions = batch_inputs["positions"]
    else:
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    bt = batch_inputs.get("block_tables")  # (B, nbmax), chunk_prefill only

    def body(carry, xs):
        x, aux = carry
        if mode in ("decode", "chunk_prefill"):
            lp, lc = xs
        else:
            lp, lc = xs, None
        if mode == "chunk_prefill" and bt is not None:
            lc = {**lc, "bt": bt}
        x, new_cache, a = _block_apply(lp, cfg, x, positions, mode, lc)
        if mode == "chunk_prefill" and bt is not None:
            # paged: bt rides in batch_inputs, only the pool is carried;
            # the CONTIGUOUS chunked path (no block tables) carries the
            # whole spec'd cache {k, v, (scales,) len} like decode does
            new_cache = {k: new_cache[k] for k in ("kp", "vp")}
        return (x, aux + a), new_cache

    if cfg.remat and mode == "train":
        body = jax.checkpoint(body, prevent_cse=False)

    if mode in ("decode", "chunk_prefill"):
        # cache leaves are stacked (L, ...): per-layer slices ride the scan.
        (x, aux), new_cache = lax.scan(body, (x, 0.0),
                                       (params["layers"], cache))
    else:
        (x, aux), new_cache = lax.scan(body, (x, 0.0), params["layers"])

    if mode == "prefill":
        x = x[:, -1:]  # serving needs only the last position's logits
    elif mode == "chunk_prefill":
        # only the last VALID position's logits matter (tail chunks are
        # padded to a bucket length)
        li = batch_inputs["last_index"].reshape(B, 1, 1)
        x = jnp.take_along_axis(x, li, axis=1)
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed_apply(params["embed"], x)
    logits = constrain(logits, ("batch", None, "vocab"))
    if mode == "train":
        return logits, aux
    return logits, new_cache
