from repro.optim.optimizers import (adamw, sgd_momentum, apply_updates,
                                    opt_state_defs, global_norm, clip_by_global_norm)
from repro.optim.schedules import constant, cosine_warmup, linear_warmup
