"""Pure-JAX optimizers with shardable pytree state.

State mirrors the parameter pytree (same logical axes -> same sharding);
moments are fp32 regardless of param dtype.  Params stay bf16 and the
update is computed in fp32 ('pure bf16 + fp32 moments'; see DESIGN.md --
the fp32-master variant is a config flag the dry-run memory table reports).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.param import ParamDef, is_def


class Optimizer(NamedTuple):
    init: Callable
    update: Callable          # (grads, state, params) -> (updates, state)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda l: (l.astype(jnp.float32) * scale), tree), norm


def adamw(lr: Callable | float, *, b1=0.9, b2=0.95, eps=1e-8,
          weight_decay=0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        c = state["count"] + 1
        lr_t = lr_fn(c)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state["mu"], grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["nu"], grads)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def upd(m, v, p):
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (-lr_t * step)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, {"mu": mu, "nu": nu, "count": c}

    return Optimizer(init, update)


def sgd_momentum(lr: Callable | float, *, momentum=0.9,
                 nesterov=False) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"mom": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        c = state["count"] + 1
        lr_t = lr_fn(c)
        mom = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32),
            state["mom"], grads)
        if nesterov:
            upd = jax.tree.map(
                lambda m, g: -(lr_t) * (momentum * m + g.astype(jnp.float32)),
                mom, grads)
        else:
            upd = jax.tree.map(lambda m: -lr_t * m, mom)
        return upd, {"mom": mom, "count": c}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)


def opt_state_defs(param_defs, optimizer: str = "adamw"):
    """ParamDef tree for the optimizer state (for dry-run shardings)."""
    def f32(d: ParamDef) -> ParamDef:
        return ParamDef(d.shape, jnp.dtype(jnp.float32), d.logical_axes,
                        "zeros", d.fan_in_axes)

    moments = {"adamw": ("mu", "nu"), "sgd": ("mom",)}[optimizer]
    out = {name: jax.tree.map(f32, param_defs, is_leaf=is_def)
           for name in moments}
    out["count"] = ParamDef((), jnp.dtype(jnp.int32), (), "zeros")
    return out
