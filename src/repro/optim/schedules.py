"""Learning-rate schedules (step -> lr, jax-traceable)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def linear_warmup(lr: float, warmup: int):
    def fn(step):
        s = step.astype(jnp.float32)
        return jnp.float32(lr) * jnp.minimum(1.0, s / max(warmup, 1))
    return fn


def cosine_warmup(lr: float, warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, s / max(warmup, 1))
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.float32(lr) * warm * cos
    return fn
