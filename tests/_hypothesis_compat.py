"""Deterministic fallback for `hypothesis` property tests.

When the real `hypothesis` package is available (see requirements-dev.txt)
the test modules use it; this shim only loads as an ImportError fallback so
the suite still collects and runs in minimal environments.

It is NOT a property-based tester: it draws a fixed, seeded sequence of
examples per test (boundary values first, then uniform random) -- enough to
exercise the same assertions deterministically, with no shrinking.
Only the strategy surface the repo's tests use is implemented:
floats / integers / lists / sampled_from, plus given() and the
settings profile API.
"""
from __future__ import annotations

import functools
import types
import zlib

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng, i):
        """i=0 -> lower boundary, i=1 -> upper boundary, else random."""
        return self._draw(rng, i)


def _floats(min_value=0.0, max_value=1.0, **_):
    def draw(rng, i):
        if i == 0:
            return float(min_value)
        if i == 1:
            return float(max_value)
        return float(rng.uniform(min_value, max_value))
    return _Strategy(draw)


def _integers(min_value, max_value, **_):
    def draw(rng, i):
        if i == 0:
            return int(min_value)
        if i == 1:
            return int(max_value)
        return int(rng.integers(min_value, max_value + 1))
    return _Strategy(draw)


def _sampled_from(options):
    opts = list(options)

    def draw(rng, i):
        if i < len(opts):
            return opts[i]
        return opts[int(rng.integers(len(opts)))]
    return _Strategy(draw)


def _lists(elements, min_size=0, max_size=10, **_):
    def draw(rng, i):
        if i == 0:
            n = min_size
        elif i == 1:
            n = max_size
        else:
            n = int(rng.integers(min_size, max_size + 1))
        return [elements.example(rng, 2) for _ in range(n)]
    return _Strategy(draw)


strategies = types.SimpleNamespace(
    floats=_floats, integers=_integers, lists=_lists,
    sampled_from=_sampled_from)


class settings:
    _profiles: dict = {}
    _current: dict = {"max_examples": 25}

    def __init__(self, **kw):
        self.kw = kw

    def __call__(self, fn):          # @settings(...) decorator form
        fn._hc_settings = self.kw
        return fn

    @classmethod
    def register_profile(cls, name, **kw):
        cls._profiles[name] = kw

    @classmethod
    def load_profile(cls, name):
        cls._current = {**cls._current, **cls._profiles.get(name, {})}


def given(*strats, **kw_strats):
    if kw_strats:
        raise NotImplementedError(
            "keyword strategies are not supported by the fallback shim")

    def deco(fn):
        @functools.wraps(fn)
        def runner(*args, **kwargs):
            n = max(int(settings._current.get("max_examples", 25)), 2)
            rng = np.random.default_rng(
                zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                try:
                    fn(*args, *(s.example(rng, i) for s in strats), **kwargs)
                except _Unsatisfied:
                    continue
        # pytest resolves fixtures through __wrapped__; without this it
        # would treat the strategy parameters as missing fixtures.
        del runner.__wrapped__
        return runner
    return deco


def assume(condition) -> bool:
    """Best-effort: the shim cannot retry a draw, so assume() only skips the
    remainder of an example by raising when the condition fails."""
    if not condition:
        raise _Unsatisfied()
    return True


class _Unsatisfied(Exception):
    pass
