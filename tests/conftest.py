import os

# Smoke tests and benches must see the real (1-device) CPU platform; only
# launch/dryrun.py forces 512 host devices (per its own first lines).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def synmnist():
    from repro.data.synthetic import make_classification_set
    return make_classification_set("synmnist", 4096, seed=1)


@pytest.fixture(scope="session")
def synmnist_test():
    from repro.data.synthetic import make_classification_set
    return make_classification_set("synmnist", 1024, seed=2)
