"""Property + unit tests for the aggregation algorithms (paper SSII-A)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback (tests/_hypothesis_compat.py)
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import aggregation as agg

settings.register_profile("fast", max_examples=25, deadline=None)
settings.load_profile("fast")


def tiny_tree(key, scale=1.0):
    k1, k2 = jax.random.split(jax.random.key(key))
    return {
        "w": jax.random.normal(k1, (4, 3)) * scale,
        "b": {"x": jax.random.normal(k2, (5,)) * scale},
    }


# ---------------- weighting schemes ----------------

@given(st.lists(st.floats(1.0, 1e4), min_size=1, max_size=12),
       st.sampled_from(["uniform", "fedavg", "linear", "polynomial",
                        "exponential"]))
def test_weights_normalised(n_data, scheme):
    s = np.arange(len(n_data), dtype=float)
    w = agg.aggregation_weights(scheme, n_data, staleness=s)
    assert w.shape == (len(n_data),)
    assert np.all(w >= 0)
    assert abs(w.sum() - 1.0) < 1e-9


@given(st.integers(2, 8))
def test_staleness_discounts_monotone(n):
    """Fresher workers must never get less weight (equal data)."""
    for scheme in ("linear", "polynomial", "exponential"):
        w = agg.aggregation_weights(scheme, [10.0] * n,
                                    staleness=np.arange(n))
        assert np.all(np.diff(w) <= 1e-12), (scheme, w)


def test_fedavg_proportional_to_data():
    w = agg.aggregation_weights("fedavg", [1, 3])
    np.testing.assert_allclose(w, [0.25, 0.75])


def test_all_stale_falls_back_to_uniform():
    w = agg.aggregation_weights("linear", [1, 1], staleness=[100, 100])
    np.testing.assert_allclose(w, [0.5, 0.5])


# ---------------- pytree merges ----------------

@given(st.integers(1, 6), st.integers(0, 2**31 - 1))
def test_weighted_average_convex_bounds(k, seed):
    rng = np.random.default_rng(seed)
    trees = [tiny_tree(i) for i in range(k)]
    w = rng.dirichlet([1.0] * k)
    out = agg.weighted_average(trees, w)
    for leaf_out, *leaves in zip(jax.tree.leaves(out),
                                 *(jax.tree.leaves(t) for t in trees)):
        stack = np.stack([np.asarray(l) for l in leaves])
        assert np.all(np.asarray(leaf_out) <= stack.max(0) + 1e-5)
        assert np.all(np.asarray(leaf_out) >= stack.min(0) - 1e-5)


def test_weighted_average_permutation_invariant():
    trees = [tiny_tree(i) for i in range(3)]
    w = np.array([0.2, 0.3, 0.5])
    a = agg.weighted_average(trees, w)
    b = agg.weighted_average([trees[2], trees[0], trees[1]],
                             [0.5, 0.2, 0.3])
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6)


def test_weighted_average_identity():
    t = tiny_tree(0)
    out = agg.weighted_average([t, t, t], [0.1, 0.4, 0.5])
    for x, y in zip(jax.tree.leaves(out), jax.tree.leaves(t)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6)


def test_async_merge_interpolates():
    a, b = tiny_tree(1), tiny_tree(2)
    out = agg.async_merge(a, b, 0.25)
    for o, x, y in zip(jax.tree.leaves(out), jax.tree.leaves(a),
                       jax.tree.leaves(b)):
        np.testing.assert_allclose(
            np.asarray(o), 0.75 * np.asarray(x) + 0.25 * np.asarray(y),
            rtol=1e-5)


@given(st.floats(0.0, 50.0))
def test_staleness_alpha_decays(s):
    a0 = agg.staleness_alpha(0.6, 0.0)
    a = agg.staleness_alpha(0.6, s)
    assert 0.0 <= a <= a0 + 1e-12


# ---------------- mixing matrices (Tier B) ----------------

@given(st.integers(2, 6), st.integers(0, 2**31 - 1))
def test_mixing_matrices_row_stochastic(P, seed):
    rng = np.random.default_rng(seed)
    w = rng.dirichlet([1.0] * P)
    M = agg.sync_mixing_matrix(w)
    np.testing.assert_allclose(M.sum(1), 1.0)
    alphas = rng.uniform(0, 1, P)
    contrib = rng.uniform(0, 1, P) + 1e-3
    M2 = agg.async_mixing_matrix(alphas, contrib)
    np.testing.assert_allclose(M2.sum(1), 1.0)
    assert np.all(M2 >= -1e-12)


def test_mix_islands_matches_manual():
    P = 3
    stacked = {"w": jnp.arange(P * 4, dtype=jnp.float32).reshape(P, 4)}
    M = jnp.asarray(np.random.default_rng(0).dirichlet([1] * P, size=P),
                    jnp.float32)
    out = agg.mix_islands(stacked, M)
    want = np.asarray(M) @ np.asarray(stacked["w"])
    np.testing.assert_allclose(np.asarray(out["w"]), want, rtol=1e-5)


def test_sync_mix_islands_consensus():
    """After a sync exchange every island holds the same average."""
    P = 4
    stacked = {"w": jnp.asarray(
        np.random.default_rng(1).normal(size=(P, 7)), jnp.float32)}
    w = np.full(P, 1.0 / P)
    out = agg.mix_islands(stacked, jnp.asarray(agg.sync_mixing_matrix(w),
                                               jnp.float32))
    arr = np.asarray(out["w"])
    for i in range(1, P):
        np.testing.assert_allclose(arr[i], arr[0], rtol=1e-5)
    np.testing.assert_allclose(arr[0], np.asarray(stacked["w"]).mean(0),
                               rtol=1e-5)
