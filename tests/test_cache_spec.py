"""CacheSpec layout layer (models/cache.py): parse/validation, sharding
fallback reporting, and NUMERIC parity of the spec'd decode caches against
the replicated-bf16 baseline.

The tentpole contract under test: a CacheSpec changes where cache bytes
live (layout) and how wide they are (dtype) but never which token greedy
decode emits --

  * ring/bf16 is TOKEN-IDENTICAL to the baseline (one global softmax max
    across segments, fp32 scores; layers.ring_decode_attention);
  * */int8 stays within quantisation tolerance at the LOGITS level;
  * contiguous chunked prefill (mode="chunk_prefill" without a block
    table) reproduces teacher-forced logits, spec'd cache included;
  * params are spec-independent: every parity test inits ONE param tree
    from the baseline model and feeds it to the spec'd model unchanged.

Plus the dryrun-facing pieces: the analytic/XLA cache-bytes calibration
pin (2x) on a rescued decode_32k cell and the `--check-fit` CI gate.
"""
import dataclasses
import json
import os
import subprocess
import sys
import warnings
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.dist import sharding
from repro.models import build_model
from repro.models import cache as kvcache

CacheSpec = kvcache.CacheSpec


# ---------------------------------------------------------------------------
# Spec parsing + abstract defs
# ---------------------------------------------------------------------------

def test_parse_name_roundtrip():
    s = CacheSpec.parse("ring:4/int8")
    assert (s.layout, s.shards, s.dtype) == ("ring", 4, "int8")
    assert s.quantized and s.name == "ring:4/int8"
    assert CacheSpec.parse(s.name) == s              # name is re-parseable
    assert CacheSpec.parse(s) is s                   # instance passthrough
    assert CacheSpec.parse("head/bf16").name == "head/bf16"
    assert not CacheSpec.parse("ring/bf16").quantized


def test_parse_auto_is_the_historical_convention():
    # "auto"/None == head/bf16 == what every model did before CacheSpec
    default = CacheSpec()
    assert CacheSpec.parse("auto") == default
    assert CacheSpec.parse(None) == default
    assert (default.layout, default.dtype, default.shards) == \
        ("head", "bf16", 0)


def test_parse_validation_errors():
    with pytest.raises(ValueError):
        CacheSpec.parse("diagonal/bf16")             # unknown layout
    with pytest.raises(ValueError):
        CacheSpec.parse("head/fp4")                  # unknown dtype
    with pytest.raises(ValueError):
        CacheSpec.parse("head:2/bf16")               # shards need ring


def test_kv_axes_by_layout():
    assert kvcache.kv_axes(CacheSpec.parse("head/bf16")) == \
        ("batch", "kv_seq", "kv_heads", None)
    assert kvcache.kv_axes(CacheSpec.parse("replicated/bf16")) == \
        ("batch", "kv_seq", None, None)
    # ring: EXPLICIT ("model",) tuple on the seq dim -- binds in
    # resolution pass 0, before the kv_heads priority wave
    assert kvcache.kv_axes(CacheSpec.parse("ring/bf16")) == \
        ("batch", ("model",), "kv_heads", None)


def test_ring_segments_halving():
    ring4 = CacheSpec.parse("ring:4/bf16")
    assert kvcache.ring_segments(ring4, 144) == 4
    assert kvcache.ring_segments(ring4, 10) == 2     # 10 % 4 -> halve
    assert kvcache.ring_segments(ring4, 7) == 1      # odd seq -> no split
    assert kvcache.ring_segments(CacheSpec.parse("head/bf16"), 144) == 1
    # shards unset: ambient "model" axis is 1 on the CPU test mesh
    assert kvcache.ring_segments(CacheSpec.parse("ring/bf16"), 144) == 1


def test_int8_defs_add_rowwise_scales():
    cfg = get_smoke_config("granite-20b")
    B, S = 2, 32
    d8 = kvcache.attention_cache_defs(cfg, B, S, spec="head/int8")
    assert d8["k"].dtype == jnp.int8
    assert d8["k_scale"].shape == (B, S, cfg.num_kv_heads, 1)
    assert d8["k_scale"].dtype == jnp.float32
    assert d8["k_scale"].logical_axes == d8["k"].logical_axes
    d16 = kvcache.attention_cache_defs(cfg, B, S, spec="head/bf16")
    assert d16["k"].dtype == jnp.bfloat16
    assert "k_scale" not in d16 and "v_scale" not in d16


# ---------------------------------------------------------------------------
# Satellite 1: sharding fallback is reported, not silent
# ---------------------------------------------------------------------------

def test_priority_fallback_recorded_and_warned():
    sharding._warned_fallbacks.clear()
    mesh = sharding.abstract_mesh((4, 8), ("data", "model"))
    report = []
    # qwen1.5-4b's footgun in miniature: 20 kv heads on an 8-wide model
    # axis -> the cache REPLICATES over "model"
    with pytest.warns(sharding.ShardingFallbackWarning):
        spec = sharding.logical_to_mesh_spec(
            ("batch", "kv_seq", "kv_heads", None), (2, 64, 20, 64), mesh,
            report=report)
    assert spec[2] is None                           # replicated, as warned
    (rec,) = report
    assert rec.logical == "kv_heads" and rec.dim == 20
    assert rec.reason == "indivisible" and "model" in rec.candidates
    assert rec.as_dict()["shape"] == (2, 64, 20, 64)


def test_ring_explicit_tuple_suppresses_fallback():
    """The ring layout DELIBERATELY gives "model" to the seq dim; the
    kv_heads dim then replicating is the contract, not a footgun -- no
    record, no warning."""
    sharding._warned_fallbacks.clear()
    mesh = sharding.abstract_mesh((4, 8), ("data", "model"))
    report = []
    with warnings.catch_warnings():
        warnings.simplefilter("error", sharding.ShardingFallbackWarning)
        spec = sharding.logical_to_mesh_spec(
            kvcache.kv_axes(CacheSpec.parse("ring/bf16")),
            (2, 64, 20, 64), mesh, report=report)
    assert spec[1] == "model" and spec[2] is None
    assert report == []


def test_fallback_warned_once_per_mesh():
    sharding._warned_fallbacks.clear()
    mesh = sharding.abstract_mesh((4, 8), ("data", "model"))
    with pytest.warns(sharding.ShardingFallbackWarning):
        sharding.logical_to_mesh_spec(
            ("batch", "kv_seq", "kv_heads", None), (2, 64, 20, 64), mesh)
    with warnings.catch_warnings():                  # second resolution: quiet
        warnings.simplefilter("error", sharding.ShardingFallbackWarning)
        sharding.logical_to_mesh_spec(
            ("batch", "kv_seq", "kv_heads", None), (2, 64, 20, 64), mesh)


# ---------------------------------------------------------------------------
# Numeric parity: ring / int8 / chunked vs the baseline convention
# ---------------------------------------------------------------------------

def _batch(model, T, B=2, seed=0):
    rng = np.random.default_rng(seed)
    cfg = model.cfg
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)),
                               jnp.int32)}
    if cfg.frontend == "vision_stub":
        b["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_len, cfg.d_model)), jnp.bfloat16)
    return b


def _spec_model(arch, spec):
    """(baseline model, spec'd model, ONE shared param tree)."""
    cfg = get_smoke_config(arch)
    base = build_model(cfg)
    other = build_model(dataclasses.replace(cfg, cache_spec=spec))
    params = base.init(jax.random.key(3))
    return base, other, params


def _greedy(model, params, batch, T, steps):
    """Prefill T tokens then decode `steps` greedy tokens; returns
    (tokens (B, steps), logits (B, steps, V) fp32)."""
    B = batch["tokens"].shape[0]
    pre = {k: (v[:, :T] if k == "tokens" else v) for k, v in batch.items()}
    logits, cache = model.apply(params, pre, mode="prefill")
    nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
    toks, logs = [], []
    for i in range(steps):
        toks.append(np.asarray(nxt))
        logits, cache = model.apply(
            params, {"tokens": nxt[:, None].astype(jnp.int32),
                     "positions": jnp.full((B, 1), T + i, jnp.int32)},
            mode="decode", cache=cache)
        logs.append(np.asarray(logits[:, 0], np.float32))
        nxt = jnp.argmax(logits[:, 0].astype(jnp.float32), axis=-1)
    return np.stack(toks, 1), np.stack(logs, 1)


# one cache-spec-capable representative per family
SPEC_FAMILIES = ["granite-20b", "qwen3-moe-235b-a22b", "phi-3-vision-4.2b"]


@pytest.mark.parametrize("arch", SPEC_FAMILIES)
def test_ring_bf16_greedy_token_identical(arch):
    """ring/bf16 re-lays the SAME bf16 numbers out across seq shards; one
    global softmax max + fp32 scores make greedy decode token-identical
    (shards forced to 4 -- the ambient CPU "model" axis is 1, which would
    degenerate to the unsegmented path)."""
    base, ring, params = _spec_model(arch, "ring:4/bf16")
    T, steps = 16, 6
    batch = _batch(base, T)
    ref_toks, ref_logs = _greedy(base, params, batch, T, steps)
    got_toks, got_logs = _greedy(ring, params, batch, T, steps)
    np.testing.assert_array_equal(got_toks, ref_toks)
    np.testing.assert_allclose(got_logs, ref_logs, rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["granite-20b", "chatglm3-6b"])
@pytest.mark.parametrize("spec", ["head/int8", "ring:4/int8"])
def test_int8_cache_logits_close(arch, spec):
    """Rowwise-int8 cache: TEACHER-FORCED decode logits stay within the
    pinned 1e-2 quantisation tolerance of the bf16 baseline -- rms error
    and scale-relative max error (max|d| / max|ref|), since rowwise int8's
    per-element floor is amax/254 ~ 0.4% of the row amax and an absolute
    max-norm of 1e-2 would be pinning noise.  Greedy argmax must agree
    exactly on every forced step.  (Teacher forcing, not greedy feedback,
    so one near-tie flip can't cascade.)"""
    base, q8, params = _spec_model(arch, spec)
    T, extra, B = 16, 4, 2
    batch = _batch(base, T + extra, B)
    pre = {k: (v[:, :T] if k == "tokens" else v) for k, v in batch.items()}

    def forced_logits(model):
        _, cache = model.apply(params, pre, mode="prefill")
        out = []
        for i in range(extra):
            logits, cache = model.apply(
                params,
                {"tokens": batch["tokens"][:, T + i: T + i + 1],
                 "positions": jnp.full((B, 1), T + i, jnp.int32)},
                mode="decode", cache=cache)
            out.append(np.asarray(logits[:, 0], np.float32))
        return np.stack(out, 1)

    ref, got = forced_logits(base), forced_logits(q8)
    d = np.abs(got - ref)
    assert np.sqrt((d ** 2).mean()) <= 1e-2, f"rms {np.sqrt((d**2).mean())}"
    rel_max = d.max() / np.abs(ref).max()
    assert rel_max <= 1e-2, f"scale-relative max error {rel_max:.4f}"
    np.testing.assert_array_equal(got.argmax(-1), ref.argmax(-1))


@pytest.mark.parametrize("arch", ["granite-20b", "chatglm3-6b"])
@pytest.mark.parametrize("spec", [None, "ring:4/bf16"])
def test_contiguous_chunk_prefill_matches_teacher_forcing(arch, spec):
    """Contiguous chunked prefill (no block table): streaming the prompt
    through fixed-size chunks into a zeros cache from cache_defs, then
    decoding, matches teacher forcing -- under the baseline spec and a
    ring spec (the fit story dryrun compiles for temp-dominated prefill
    cells)."""
    cfg = get_smoke_config(arch)
    if spec:
        cfg = dataclasses.replace(cfg, cache_spec=spec)
    model = build_model(cfg)
    params = model.init(jax.random.key(5))
    from repro.models.param import is_def
    B, T, extra, chunk = 2, 16, 3, 8
    batch = _batch(model, T + extra, B, seed=5)
    ref_logits, _ = model.apply(params, batch, mode="train")

    cache = jax.tree.map(lambda d: jnp.zeros(d.shape, d.dtype),
                         model.cache_defs(B, T + extra + 1), is_leaf=is_def)
    logits = None
    for pos in range(0, T, chunk):
        logits, cache = model.apply(
            params,
            {"tokens": batch["tokens"][:, pos: pos + chunk],
             "positions": jnp.broadcast_to(
                 jnp.arange(pos, pos + chunk, dtype=jnp.int32), (B, chunk)),
             "last_index": jnp.full((B,), chunk - 1, jnp.int32)},
            mode="chunk_prefill", cache=cache)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0], np.float32),
        np.asarray(ref_logits[:, T - 1], np.float32), rtol=2e-2, atol=2e-2)

    for i in range(extra):                      # decode continues the cache
        logits, cache = model.apply(
            params,
            {"tokens": batch["tokens"][:, T + i: T + i + 1],
             "positions": jnp.full((B, 1), T + i, jnp.int32)},
            mode="decode", cache=cache)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(ref_logits[:, T + i], np.float32),
            rtol=2e-2, atol=2e-2)


def test_params_are_spec_independent():
    """build_model under any CacheSpec yields the SAME param tree: the
    spec owns the cache, never the weights (serve.py swaps specs by
    rebuilding the model around already-initialised params)."""
    cfg = get_smoke_config("granite-20b")
    base = build_model(cfg).param_defs()
    for spec in ("ring:4/bf16", "head/int8", "replicated/bf16"):
        other = build_model(
            dataclasses.replace(cfg, cache_spec=spec)).param_defs()
        assert jax.tree.structure(base) == jax.tree.structure(other)
        assert jax.tree.map(lambda a, b: a.shape == b.shape, base, other)


# ---------------------------------------------------------------------------
# Policy products (the analytic side the parity backs)
# ---------------------------------------------------------------------------

def test_serve_product_candidates_shape():
    from repro.dist import policy as dist_policy
    from repro.models.config import ShapeConfig
    model = build_model(get_smoke_config("granite-20b"))
    dec = dist_policy.serve_product_candidates(
        model, ShapeConfig("serve", "decode", 32768, 8))
    specs = {cs for _, cs, _ in dec}
    assert specs == set(dist_policy.CACHE_SPEC_CANDIDATES)
    assert not any(ch for _, _, ch in dec)           # chunking is prefill-only
    pre = dist_policy.serve_product_candidates(
        model, ShapeConfig("serve", "prefill", 32768, 8))
    assert any(ch for _, _, ch in pre)               # long prefill: chunked
    # no-cache families never get spec or chunk candidates
    ssm = build_model(get_smoke_config("falcon-mamba-7b"))
    assert all(cs is None and not ch for _, cs, ch in
               dist_policy.serve_product_candidates(
                   ssm, ShapeConfig("serve", "decode", 32768, 8)))


def test_analytic_prefill_baseline_excludes_cache_bytes():
    """The no-spec prefill eval keeps the HISTORICAL convention (cache not
    counted against peak); a spec'd eval counts it -- so adding the
    product layer shifted no baseline number."""
    from repro.dist import policy as dist_policy
    from repro.models.config import ShapeConfig
    mesh = sharding.abstract_mesh((4, 8), ("data", "model"))
    model = build_model(get_smoke_config("granite-20b"))
    shape = ShapeConfig("serve", "prefill", 32768, 8)
    plain = dist_policy.analytic_eval(model, shape, mesh, "fsdp")
    spec = dist_policy.analytic_eval(model, shape, mesh, "fsdp",
                                     cache_spec="head/bf16")
    assert plain.detail["cache_bytes"] == 0.0
    assert spec.detail["cache_bytes"] > 0.0
    assert spec.hbm_bytes > plain.hbm_bytes


# ---------------------------------------------------------------------------
# Dryrun: calibration pin + the --check-fit CI gate (subprocess)
# ---------------------------------------------------------------------------

def _dryrun_env():
    return dict(os.environ, REPRO_DRYRUN_DIR="dryrun_test",
                PYTHONPATH="src" + os.pathsep
                + os.environ.get("PYTHONPATH", ""))


def test_dryrun_cache_spec_rescues_decode_32k_and_calibrates():
    """qwen1.5-4b decode_32k single was THE motivating no-fit cell (20 kv
    heads -> replicated 432 GB/dev cache).  The product frontier must
    rescue it with a spec'd cache, and the analytic cache bytes must stay
    within 2x of the XLA-derived argument bytes (satellite calibration
    pin)."""
    root = Path(__file__).resolve().parents[1]
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "qwen1.5-4b", "--shape", "decode_32k", "--mesh", "single",
         "--force"],
        cwd=root, env=_dryrun_env(), capture_output=True, text=True,
        timeout=600)
    art = root / "artifacts" / "dryrun_test" / \
        "qwen1.5-4b__decode_32k__single.json"
    try:
        assert r.returncode == 0, r.stderr[-2000:]
        rec = json.loads(art.read_text())
        d = rec["layout_decision"]
        assert d["fits"], d
        assert d["cache_spec"], "rescue must come from a spec'd cache"
        e = rec["entries"]["decode_step"]
        ours, xla = e["cache_bytes_analytic"], e["cache_bytes_xla_derived"]
        assert xla > 0
        assert 0.5 * xla <= ours <= 2.0 * xla, \
            f"cache bytes {ours:.3g} vs XLA-derived {xla:.3g}"
    finally:
        if art.exists():
            art.unlink()


def test_check_fit_gate_passes_both_meshes():
    """`dryrun --check-fit --mesh both` is the CI scale gate: every serve
    cell (both meshes) has >=1 fitting (weight layout x cache spec)
    product, analytically, in seconds."""
    root = Path(__file__).resolve().parents[1]
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--check-fit",
         "--mesh", "both"],
        cwd=root, env=_dryrun_env(), capture_output=True, text=True,
        timeout=600)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    assert "every serve cell has >=1 fitting" in r.stdout
