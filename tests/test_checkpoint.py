"""Fault-tolerance substrate: atomic checkpoints, rotation, elastic restore."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree


def tree(v=1.0):
    return {"w": jnp.full((4, 2), v, jnp.bfloat16),
            "o": {"mu": jnp.full((4, 2), v / 2, jnp.float32)}}


def test_save_load_roundtrip(tmp_path):
    p = tmp_path / "x.npz"
    save_pytree(tree(3.0), p)
    out = load_pytree(p, tree())
    np.testing.assert_allclose(np.asarray(out["w"], np.float32), 3.0)


def test_manager_roundtrip_and_manifest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(10, params=tree(1.0), opt_state={"c": jnp.int32(7)},
             extra={"round": 4, "policy_T": 3.5})
    step, params, opt, extra = mgr.restore(
        params_like=tree(), opt_state_like={"c": jnp.int32(0)})
    assert step == 10 and extra["round"] == 4
    np.testing.assert_allclose(np.asarray(params["w"], np.float32), 1.0)
    assert int(opt["c"]) == 7


def test_rotation_keeps_newest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, params=tree(float(s)))
    assert mgr.all_steps() == [3, 4]
    step, params, _, _ = mgr.restore(params_like=tree())
    assert step == 4
    np.testing.assert_allclose(np.asarray(params["w"], np.float32), 4.0)


def test_restore_specific_step(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=5)
    for s in (1, 2):
        mgr.save(s, params=tree(float(s)))
    step, params, _, _ = mgr.restore(params_like=tree(), step=1)
    assert step == 1
    np.testing.assert_allclose(np.asarray(params["w"], np.float32), 1.0)


def test_no_partial_checkpoint_on_disk(tmp_path):
    """Atomic publish: no .tmp dirs left behind after a successful save."""
    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save(5, params=tree())
    assert not [p for p in tmp_path.iterdir() if p.name.startswith(".tmp")]


def test_elastic_restore_new_sharding(tmp_path):
    """Restore with an explicit (single-device) sharding tree -- the elastic
    re-shard path: checkpoint saved without mesh info, loaded onto whatever
    mesh is live."""
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, params=tree(2.0))
    from repro.launch.mesh import make_mesh  # version-compat Auto axes
    mesh = make_mesh((1,), ("data",))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    shardings = jax.tree.map(lambda _: sh, tree())
    step, params, _, _ = mgr.restore(params_like=tree(), shardings=shardings)
    assert params["w"].sharding == sh


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    with pytest.raises(FileNotFoundError):
        mgr.restore(params_like=tree())


def test_overwrite_same_step_keeps_newest(tmp_path):
    """Re-saving a step (crash-retry of the same round) replaces it and the
    overwrite has NO crash window: at every point a loadable copy of the
    step exists as step_X or .old_step_X."""
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(7, params=tree(1.0))
    mgr.save(7, params=tree(2.0))
    assert mgr.all_steps() == [7]
    _, params, _, _ = mgr.restore(params_like=tree())
    np.testing.assert_allclose(np.asarray(params["w"], np.float32), 2.0)
    # no rename-aside garbage after a clean overwrite
    assert not [p for p in tmp_path.iterdir() if p.name.startswith(".old")]


def test_recover_interrupted_overwrite(tmp_path):
    """Simulate a crash BETWEEN un-publish and re-publish: step_X has been
    renamed aside to .old_step_X and the new copy never landed.  A fresh
    manager must restore the old copy -- the previous rmtree-then-replace
    save() lost the checkpoint in exactly this window."""
    import os

    mgr = CheckpointManager(tmp_path, keep=3)
    ckpt = mgr.save(3, params=tree(5.0))
    os.replace(ckpt, tmp_path / ".old_step_0000000003")  # crash mid-overwrite
    assert CheckpointManager(tmp_path).all_steps() == [3]
    _, params, _, _ = CheckpointManager(tmp_path).restore(params_like=tree())
    np.testing.assert_allclose(np.asarray(params["w"], np.float32), 5.0)


def test_recover_discards_stale_leftovers(tmp_path):
    """A .old with a published sibling (crash after publish) and stale .tmp
    dirs are garbage: _recover deletes both, keeping the published copy."""
    import shutil

    mgr = CheckpointManager(tmp_path, keep=3)
    ckpt = mgr.save(3, params=tree(9.0))
    shutil.copytree(ckpt, tmp_path / ".old_step_0000000003")
    (tmp_path / ".tmp_step_0000000004").mkdir()
    mgr2 = CheckpointManager(tmp_path)
    assert mgr2.all_steps() == [3]
    leftovers = [p.name for p in tmp_path.iterdir()
                 if p.name.startswith((".old", ".tmp"))]
    assert leftovers == []
    _, params, _, _ = mgr2.restore(params_like=tree())
    np.testing.assert_allclose(np.asarray(params["w"], np.float32), 9.0)
