"""The vmap cohort path must equal the sequential per-worker fold."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, federated
from repro.core.client import LocalTrainer
from repro.models import build_model
from repro.models.config import ModelConfig

MLP = ModelConfig(name="tiny-mlp", family="cnn", num_layers=0, d_model=48,
                  img_hw=28, img_c=1, n_classes=10, remat=False)


def _fleet(synmnist, n_workers=5, shard=96, seed=0):
    imgs, labels = synmnist
    model = build_model(MLP)
    trainer = LocalTrainer(model, lr=0.05, batch_size=32)
    params = model.init(jax.random.key(seed))
    shards = [(imgs[i * shard:(i + 1) * shard],
               labels[i * shard:(i + 1) * shard]) for i in range(n_workers)]
    keys = [jax.random.key(100 + i) for i in range(n_workers)]
    return trainer, params, shards, keys


def test_cohort_matches_sequential_members(synmnist):
    trainer, params, shards, keys = _fleet(synmnist)
    stacked = federated.cohort_train(trainer, params, shards, keys, 2)
    for i, ((xi, yi), k) in enumerate(zip(shards, keys)):
        seq = trainer.train(params, jnp.asarray(xi), jnp.asarray(yi), k, 2)
        for a, b in zip(jax.tree.leaves(seq),
                        jax.tree.leaves(federated.island_slice(stacked, i))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_cohort_fold_matches_sequential_fold(synmnist):
    """Aggregate of the batched step == aggregate of the Python loop."""
    trainer, params, shards, keys = _fleet(synmnist)
    n = np.array([x.shape[0] for x, _ in shards], np.float64)
    w = n / n.sum()
    seq_fold = aggregation.weighted_average(
        [trainer.train(params, jnp.asarray(x), jnp.asarray(y), k, 2)
         for (x, y), k in zip(shards, keys)], w)
    stacked = federated.cohort_train(trainer, params, shards, keys, 2)
    vmap_fold = federated.island_slice(
        federated.fl_aggregate(
            stacked, jnp.asarray(aggregation.sync_mixing_matrix(w),
                                 jnp.float32)), 0)
    for a, b in zip(jax.tree.leaves(seq_fold), jax.tree.leaves(vmap_fold)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_sim_cohort_path_matches_sequential_path(synmnist, synmnist_test):
    """FLSimulation with cohort batching on vs off: same timing stream,
    same accuracy trajectory (within vmap reduction-order jitter)."""
    from test_events import make_sim
    on = make_sim(synmnist, synmnist_test, n_workers=4, seed=5)
    assert on.cohort
    off = make_sim(synmnist, synmnist_test, n_workers=4, seed=5)
    off.cohort = False
    r_on = on.run_sync(rounds=3)
    r_off = off.run_sync(rounds=3)
    assert [r.time for r in r_on.records] == [r.time for r in r_off.records]
    np.testing.assert_allclose([r.acc for r in r_on.records],
                               [r.acc for r in r_off.records], atol=1e-3)
    for a, b in zip(jax.tree.leaves(r_on.final_params),
                    jax.tree.leaves(r_off.final_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
