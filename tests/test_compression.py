"""int8 delta compression: error bounds + error-feedback unbiasedness."""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback (tests/_hypothesis_compat.py)
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import compression as comp

settings.register_profile("fast", max_examples=20, deadline=None)
settings.load_profile("fast")


@given(st.integers(0, 2**31 - 1), st.integers(1, 2000),
       st.sampled_from([32, 64, 256, 500]))
def test_quant_error_bound(seed, n, block):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n,)) * rng.uniform(0.1, 10), jnp.float32)
    q, s = comp.quantize_blockwise(x, block=block)
    deq = comp.dequantize_blockwise(q, s, x.shape)
    # per-block error <= scale/2 per element
    err = np.abs(np.asarray(deq - x))
    scales = np.repeat(np.asarray(s), block)[: n]
    assert np.all(err <= scales / 2 + 1e-7)


def test_tree_roundtrip_structure():
    t = {"a": jnp.ones((3, 5), jnp.bfloat16), "b": jnp.zeros(7)}
    c = comp.compress_tree(t)
    out = comp.decompress_tree(c)
    assert out["a"].shape == (3, 5) and str(out["a"].dtype) == "bfloat16"
    np.testing.assert_allclose(np.asarray(out["b"]), 0.0)


def test_compressed_bytes_smaller():
    t = {"w": jnp.ones((1024, 64), jnp.float32)}
    raw = 1024 * 64 * 4
    assert comp.compressed_bytes(t) < raw / 3


def test_compressed_bytes_matches_compress_tree_block():
    """The byte count must agree EXACTLY with the wire payload at any
    block size: quantize_blockwise pads to a block multiple, so the wire
    carries nblocks*block int8 bytes + 4 per scale (an earlier count
    dropped the pad)."""
    t = {"w": jnp.ones((300, 7), jnp.float32), "b": jnp.ones((5,))}
    for block in (32, 64, 100, 256):
        c = comp.compress_tree(t, block=block)
        actual = sum(d["q"].size + 4 * d["scale"].size
                     for d in jax.tree.leaves(c, is_leaf=comp._is_cleaf))
        assert comp.compressed_bytes(t, block=block) == actual
    # different blocks really change the count; on small leaves the pad
    # dominates, so big blocks cost MORE bytes than small ones
    assert comp.compressed_bytes(t, block=32) < \
        comp.compressed_bytes(t, block=256)


def test_compressed_bytes_modes_and_abstract_leaves():
    """Mode accounting: q8_topk < topk < q8 < none on a big enough leaf;
    works on abstract (ShapeDtypeStruct) leaves too."""
    t = {"w": jnp.ones((4096, 64), jnp.float32)}
    b = {m: comp.compressed_bytes(t, mode=m, k_frac=0.05)
         for m in ("none", "q8", "topk", "q8_topk")}
    assert b["q8_topk"] < b["topk"] < b["q8"] < b["none"]
    assert b["none"] == 4096 * 64 * 4
    abstract = {"w": jax.ShapeDtypeStruct((4096, 64), jnp.float32)}
    for m in ("none", "q8", "topk", "q8_topk", "q8_rowwise"):
        assert comp.compressed_bytes(abstract, mode=m, k_frac=0.05) == \
            comp.compressed_bytes(t, mode=m, k_frac=0.05)
    # rowwise: n int8 + one fp32 scale per last-dim row
    assert comp.compressed_bytes(t, mode="q8_rowwise") == \
        4096 * 64 + 4 * 4096


def test_sparsify_topk_and_roundtrip():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(40, 5)), jnp.float32)
    idx, val = comp.sparsify_topk(x, k_frac=0.1)           # k = 20
    assert idx.shape == (20,) and val.shape == (20,)
    flat = np.asarray(x).reshape(-1)
    kept = set(np.argsort(np.abs(flat))[-20:])
    assert set(np.asarray(idx)) == kept
    np.testing.assert_allclose(np.asarray(val), flat[np.asarray(idx)])
    for mode in ("topk", "q8_topk"):
        c = comp.compress_tree({"x": x}, mode=mode, k_frac=0.1)
        out = comp.decompress_tree(c)["x"]
        assert out.shape == x.shape and out.dtype == x.dtype
        got = np.asarray(out).reshape(-1)
        dropped = sorted(set(range(200)) - kept)
        np.testing.assert_allclose(got[dropped], 0.0)      # dropped -> 0
        tol = 0 if mode == "topk" else np.abs(flat).max() / 127
        np.testing.assert_allclose(got[np.asarray(idx)], flat[np.asarray(idx)],
                                   atol=tol + 1e-7)


def test_topk_mask_threshold_semantics():
    x = jnp.asarray([[0.1, -5.0, 0.2, 3.0], [1.0, 0.0, -2.0, 0.5]],
                    jnp.float32)
    m = np.asarray(comp.topk_mask(x, k_frac=0.5, batch_dims=1))
    np.testing.assert_array_equal(m, [[False, True, False, True],
                                      [True, False, True, False]])
    # all-zero input keeps nothing (scale-clamp path upstream)
    assert not np.asarray(comp.topk_mask(jnp.zeros((3, 8)), k_frac=0.5,
                                         batch_dims=1)).any()


def test_rowwise_blockwise_cross_layout_equivalence():
    """The shared _symmetric_q8 core makes the two scale layouts agree:
    rowwise on an (nblocks, block) view == blockwise on the flat array."""
    rng = np.random.default_rng(3)
    block = 64
    x = jnp.asarray(rng.normal(size=(6 * block,)), jnp.float32)
    qb, sb = comp.quantize_blockwise(x, block=block)
    qr, sr = comp.quantize_rowwise(x.reshape(6, block))
    np.testing.assert_array_equal(np.asarray(qb), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(sb), np.asarray(sr)[:, 0],
                               rtol=1e-7)
    deq_b = comp.dequantize_blockwise(qb, sb, (6, block))
    deq_r = comp.dequantize_rowwise(qr, sr)
    np.testing.assert_allclose(np.asarray(deq_b), np.asarray(deq_r),
                               rtol=1e-7)


def test_error_feedback_unbiased_over_rounds():
    """sum of decompressed sends ~ sum of true deltas (EF-SGD property)."""
    rng = np.random.default_rng(0)
    like = {"w": jnp.zeros((512,), jnp.float32)}
    ef = comp.ErrorFeedback(like)
    total_true = np.zeros(512)
    total_sent = np.zeros(512)
    for i in range(30):
        delta = {"w": jnp.asarray(rng.normal(size=512) * 0.01, jnp.float32)}
        ctree = ef.compress(delta)
        sent = comp.decompress_tree(jax.tree.map(
            lambda d: dict(d, dtype="float32"), ctree,
            is_leaf=lambda x: isinstance(x, dict) and "q" in x))
        total_true += np.asarray(delta["w"])
        total_sent += np.asarray(sent["w"])
    resid = np.abs(np.asarray(ef.residual["w"]))
    np.testing.assert_allclose(total_sent + np.asarray(ef.residual["w"]),
                               total_true, atol=1e-4)
    assert resid.max() < 0.01  # residual stays bounded (no drift)


@given(st.integers(0, 2**31 - 1), st.integers(5, 25),
       st.sampled_from([64, 256, 300]))
def test_error_feedback_converges_property(seed, rounds, block):
    """Property form: for any seed/round-count/block, the cumulative
    TRANSMITTED delta equals the cumulative true delta up to the current
    residual, and the residual is bounded by one quantisation step."""
    rng = np.random.default_rng(seed)
    n = 192
    like = {"w": jnp.zeros((n,), jnp.float32)}
    ef = comp.ErrorFeedback(like)
    total_true = np.zeros(n)
    total_sent = np.zeros(n)
    max_step = 0.0
    for _ in range(rounds):
        delta = {"w": jnp.asarray(rng.normal(size=n) * 0.02, jnp.float32)}
        ctree = ef.compress(delta, block=block)
        sent = comp.decompress_tree(jax.tree.map(
            lambda d: dict(d, dtype="float32"), ctree,
            is_leaf=lambda x: isinstance(x, dict) and "q" in x))
        total_true += np.asarray(delta["w"])
        total_sent += np.asarray(sent["w"])
        max_step = max(max_step, float(np.max(np.asarray(
            ctree["w"]["scale"]))))
    resid = np.asarray(ef.residual["w"])
    # exact bookkeeping identity: sent + residual == true (fp32 rounding)
    np.testing.assert_allclose(total_sent + resid, total_true,
                               atol=1e-4 * rounds)
    # residual never exceeds half of the largest quantisation step seen
    assert np.abs(resid).max() <= max_step / 2 + 1e-6


@given(st.sampled_from(["topk", "q8_topk"]), st.integers(0, 2**31 - 1))
def test_error_feedback_carries_topk_drops(mode, seed):
    """The residual carries the entries top-k dropped: the bookkeeping
    identity sent + residual == true holds for the sparse modes too."""
    rng = np.random.default_rng(seed)
    n = 192
    ef = comp.ErrorFeedback({"w": jnp.zeros((n,), jnp.float32)})
    total_true = np.zeros(n)
    total_sent = np.zeros(n)
    for _ in range(10):
        delta = {"w": jnp.asarray(rng.normal(size=n) * 0.02, jnp.float32)}
        ctree = ef.compress(delta, mode=mode, k_frac=0.1)
        sent = comp.decompress_tree(jax.tree.map(
            lambda d: dict(d, dtype="float32"), ctree,
            is_leaf=comp._is_cleaf))
        total_true += np.asarray(delta["w"])
        total_sent += np.asarray(sent["w"])
    np.testing.assert_allclose(total_sent + np.asarray(ef.residual["w"]),
                               total_true, atol=1e-3)
