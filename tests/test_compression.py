"""int8 delta compression: error bounds + error-feedback unbiasedness."""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback (tests/_hypothesis_compat.py)
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import compression as comp

settings.register_profile("fast", max_examples=20, deadline=None)
settings.load_profile("fast")


@given(st.integers(0, 2**31 - 1), st.integers(1, 2000),
       st.sampled_from([32, 64, 256, 500]))
def test_quant_error_bound(seed, n, block):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n,)) * rng.uniform(0.1, 10), jnp.float32)
    q, s = comp.quantize_blockwise(x, block=block)
    deq = comp.dequantize_blockwise(q, s, x.shape)
    # per-block error <= scale/2 per element
    err = np.abs(np.asarray(deq - x))
    scales = np.repeat(np.asarray(s), block)[: n]
    assert np.all(err <= scales / 2 + 1e-7)


def test_tree_roundtrip_structure():
    t = {"a": jnp.ones((3, 5), jnp.bfloat16), "b": jnp.zeros(7)}
    c = comp.compress_tree(t)
    out = comp.decompress_tree(c)
    assert out["a"].shape == (3, 5) and str(out["a"].dtype) == "bfloat16"
    np.testing.assert_allclose(np.asarray(out["b"]), 0.0)


def test_compressed_bytes_smaller():
    t = {"w": jnp.ones((1024, 64), jnp.float32)}
    raw = 1024 * 64 * 4
    assert comp.compressed_bytes(t) < raw / 3


def test_compressed_bytes_matches_compress_tree_block():
    """The byte count must agree with the actual compressed form at a
    NON-default block size (it used to hardcode 256)."""
    t = {"w": jnp.ones((300, 7), jnp.float32), "b": jnp.ones((5,))}
    for block in (32, 64, 100, 256):
        c = comp.compress_tree(t, block=block)
        actual = sum(d["q"].size + 4 * d["scale"].size
                     for d in jax.tree.leaves(
                         c, is_leaf=lambda x: isinstance(x, dict) and "q" in x))
        # compressed_bytes counts n payload int8 bytes (not the pad) plus
        # 4 bytes per block scale
        n = sum(leaf.size for leaf in jax.tree.leaves(t))
        nblocks = sum(d["scale"].size for d in jax.tree.leaves(
            c, is_leaf=lambda x: isinstance(x, dict) and "q" in x))
        assert comp.compressed_bytes(t, block=block) == n + 4 * nblocks
        assert comp.compressed_bytes(t, block=block) <= actual
    # different blocks really change the count
    assert comp.compressed_bytes(t, block=32) > \
        comp.compressed_bytes(t, block=256)


def test_error_feedback_unbiased_over_rounds():
    """sum of decompressed sends ~ sum of true deltas (EF-SGD property)."""
    rng = np.random.default_rng(0)
    like = {"w": jnp.zeros((512,), jnp.float32)}
    ef = comp.ErrorFeedback(like)
    total_true = np.zeros(512)
    total_sent = np.zeros(512)
    for i in range(30):
        delta = {"w": jnp.asarray(rng.normal(size=512) * 0.01, jnp.float32)}
        ctree = ef.compress(delta)
        sent = comp.decompress_tree(jax.tree.map(
            lambda d: dict(d, dtype="float32"), ctree,
            is_leaf=lambda x: isinstance(x, dict) and "q" in x))
        total_true += np.asarray(delta["w"])
        total_sent += np.asarray(sent["w"])
    resid = np.abs(np.asarray(ef.residual["w"]))
    np.testing.assert_allclose(total_sent + np.asarray(ef.residual["w"]),
                               total_true, atol=1e-4)
    assert resid.max() < 0.01  # residual stays bounded (no drift)


@given(st.integers(0, 2**31 - 1), st.integers(5, 25),
       st.sampled_from([64, 256, 300]))
def test_error_feedback_converges_property(seed, rounds, block):
    """Property form: for any seed/round-count/block, the cumulative
    TRANSMITTED delta equals the cumulative true delta up to the current
    residual, and the residual is bounded by one quantisation step."""
    rng = np.random.default_rng(seed)
    n = 192
    like = {"w": jnp.zeros((n,), jnp.float32)}
    ef = comp.ErrorFeedback(like)
    total_true = np.zeros(n)
    total_sent = np.zeros(n)
    max_step = 0.0
    for _ in range(rounds):
        delta = {"w": jnp.asarray(rng.normal(size=n) * 0.02, jnp.float32)}
        ctree = ef.compress(delta, block=block)
        sent = comp.decompress_tree(jax.tree.map(
            lambda d: dict(d, dtype="float32"), ctree,
            is_leaf=lambda x: isinstance(x, dict) and "q" in x))
        total_true += np.asarray(delta["w"])
        total_sent += np.asarray(sent["w"])
        max_step = max(max_step, float(np.max(np.asarray(
            ctree["w"]["scale"]))))
    resid = np.asarray(ef.residual["w"])
    # exact bookkeeping identity: sent + residual == true (fp32 rounding)
    np.testing.assert_allclose(total_sent + resid, total_true,
                               atol=1e-4 * rounds)
    # residual never exceeds half of the largest quantisation step seen
    assert np.abs(resid).max() <= max_step / 2 + 1e-6
