"""Tests for the Eq. 4 cost model."""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback (tests/_hypothesis_compat.py)
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import cost_model as cm

settings.register_profile("fast", max_examples=25, deadline=None)
settings.load_profile("fast")


@given(st.integers(1, 10_000), st.floats(0.1, 1.0), st.floats(1e9, 4e9))
def test_estimate_scales(n_data, prop, freq):
    p = cm.WorkerProfile(wid=0, cpu_freq=freq, cpu_prop=prop, n_data=n_data)
    t = cm.estimate_t_one(p, t_onedata_server=1e-3, server_freq=2e9)
    t2 = cm.estimate_t_one(
        cm.WorkerProfile(wid=0, cpu_freq=freq, cpu_prop=prop,
                         n_data=2 * n_data),
        t_onedata_server=1e-3, server_freq=2e9)
    assert t >= 0
    assert np.isclose(t2, 2 * t)          # linear in data size (Eq. 4)


def test_contention_slows_worker():
    base = dict(wid=0, cpu_freq=2e9, n_data=100)
    fast = cm.estimate_t_one(cm.WorkerProfile(cpu_prop=1.0, **base),
                             t_onedata_server=1e-3, server_freq=2e9)
    slow = cm.estimate_t_one(cm.WorkerProfile(cpu_prop=0.5, **base),
                             t_onedata_server=1e-3, server_freq=2e9)
    assert slow > fast


def test_observe_ewma_converges():
    s = cm.WorkerStats(wid=0, t_one=100.0, t_transmit=10.0, n_data=5)
    for _ in range(20):
        s.observe(1.0, 0.1)
    assert abs(s.t_one - 1.0) < 1e-3      # estimates -> measurements
    assert abs(s.t_transmit - 0.1) < 1e-4


def test_heterogeneous_profiles_deterministic():
    a = cm.heterogeneous_profiles(5, [10] * 5, seed=3)
    b = cm.heterogeneous_profiles(5, [10] * 5, seed=3)
    assert all(x.speed_factor == y.speed_factor for x, y in zip(a, b))
    assert all(1.0 <= p.speed_factor <= 4.0 for p in a)


def test_transmit_time_positive_and_monotone_in_bytes():
    p = cm.WorkerProfile(wid=0, bandwidth=1e6)
    assert p.true_t_transmit(10**6) < p.true_t_transmit(10**7)
