"""Federated data pipeline tests (Tables III/IV + partitioners)."""
import numpy as np
import pytest

from repro.data.partition import (dirichlet_partition, paper_table3,
                                  paper_table4, partition_by_batches)
from repro.data.synthetic import (batch_token_stream,
                                  make_classification_set, make_token_stream)


def test_table3_totals_match_paper():
    # configs 1-3 share one total; 4-6 share another (paper SSIV-A)
    for cfgs, total in (((1, 2, 3), 10), ((4, 5, 6), 100)):
        for c in cfgs:
            kind, rows = paper_table3(c)
            assert len(rows) == 10
            assert sum(rows) == total, (c, rows)
    assert paper_table3(1)[0] == "synmnist"
    assert paper_table3(4)[0] == "syncifar"


def test_table4_totals_match_paper():
    for cfgs, total in (((1, 2, 3), 30), ((4, 5, 6), 300)):
        for c in cfgs:
            kind, rows = paper_table4(c)
            assert len(rows) == 30
            assert sum(rows) == total, (c, rows)


def test_sequential_configs_put_all_data_on_w1():
    for table, cfg in ((paper_table3, 1), (paper_table3, 4),
                       (paper_table4, 1), (paper_table4, 4)):
        _, rows = table(cfg)
        assert rows[0] == sum(rows)


def test_partition_disjoint_and_sized():
    imgs, labels = make_classification_set("synmnist", 2048, seed=0)
    shards = partition_by_batches(imgs, labels, [4, 0, 2], batch_size=64,
                                  seed=1)
    assert [s[0].shape[0] for s in shards] == [256, 0, 128]
    # disjointness via fingerprints
    fps = [set(map(lambda a: a.tobytes()[:64], s[0])) for s in shards if
           len(s[0])]
    assert not (fps[0] & fps[1])


def test_dirichlet_partition_covers_all():
    imgs, labels = make_classification_set("synmnist", 1024, seed=0)
    shards = dirichlet_partition(imgs, labels, 5, alpha=0.5, seed=0)
    assert sum(s[0].shape[0] for s in shards) == 1024


def test_classification_set_learnable_classes():
    imgs, labels = make_classification_set("synmnist", 512, seed=0)
    assert imgs.shape == (512, 28, 28, 1)
    assert imgs.min() >= 0.0 and imgs.max() <= 1.0
    assert len(np.unique(labels)) == 10
    # class means must differ (prototype structure present)
    m0 = imgs[labels == 0].mean(0)
    m1 = imgs[labels == 1].mean(0)
    assert np.abs(m0 - m1).mean() > 0.05


def test_token_stream_batching_deterministic():
    s = make_token_stream(1000, 100_000, seed=0)
    assert s.min() >= 0 and s.max() < 1000
    x1, y1 = batch_token_stream(s, 4, 128, step=3)
    x2, y2 = batch_token_stream(s, 4, 128, step=3)
    np.testing.assert_array_equal(x1, x2)
    # labels are next-token shifted
    np.testing.assert_array_equal(x1.reshape(-1)[1:], y1.reshape(-1)[:-1])
