"""Decode-path correctness: prefill + single-token decode must reproduce the
teacher-forced logits at the next position (per model family).

This is the strongest serving-correctness test we can run on CPU: it
exercises KV caches, ring buffers (SWA), SSM/RG-LRU state carry, and the
cross-attention cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model

# one representative per decode code path
FAMILIES = [
    "granite-20b",            # dense MQA, full attention
    "chatglm3-6b",            # GQA + partial rope + qkv bias
    "qwen3-moe-235b-a22b",    # MoE decode
    "falcon-mamba-7b",        # SSM state
    "recurrentgemma-9b",      # hybrid RG-LRU + local attention ring
    "seamless-m4t-large-v2",  # enc-dec with cross-attention cache
]


def _batch(model, T, B=2, seed=0):
    rng = np.random.default_rng(seed)
    cfg = model.cfg
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)),
                               jnp.int32)}
    if cfg.frontend == "vision_stub":
        b["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_len, cfg.d_model)), jnp.bfloat16)
    if cfg.is_encdec:
        b["frames"] = jnp.asarray(rng.normal(size=(B, T, cfg.d_model)),
                                  jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", FAMILIES)
def test_prefill_then_decode_matches_teacher_forcing(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    T, B = 16, 2
    full = _batch(model, T + 1, B)

    # teacher-forced reference: logits at position T-? -> prediction for
    # position T given tokens[:T]; compare logits AT the last position.
    tf_in = {k: (v[:, : T] if k in ("tokens",) else v)
             for k, v in full.items()}
    # run T+1 tokens through train mode, take logits at index T
    ref_logits, _ = model.apply(params, full, mode="train")
    ref_last = np.asarray(ref_logits[:, T, :], np.float32)

    # prefill on T tokens, then decode token T
    _, cache = model.apply(params, tf_in, mode="prefill")
    dec_in = {"tokens": full["tokens"][:, T: T + 1],
              "positions": jnp.full((B, 1), T, jnp.int32)}
    dec_logits, _ = model.apply(params, dec_in, mode="decode", cache=cache)
    got = np.asarray(dec_logits[:, 0, :], np.float32)

    np.testing.assert_allclose(got, ref_last, rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["granite-20b", "chatglm3-6b"])
def test_paged_chunk_prefill_then_decode_matches_teacher_forcing(arch):
    """Block-table paged path at the LOGITS level: chunked/bucketed
    prefill through the block pool, then paged decode steps, must match
    teacher forcing like the contiguous path does (granite = MQA,
    chatglm = GQA + partial rope + qkv bias)."""
    from repro.models.param import is_def

    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(7))
    B, T, extra = 1, 13, 3
    bs, chunk, num_blocks = 4, 8, 8
    L = cfg.num_layers
    full = _batch(model, T + extra, B, seed=7)
    ref_logits, _ = model.apply(params, full, mode="train")

    defs = model.paged_cache_defs(B, num_blocks, bs, num_blocks)
    zeros = jax.tree.map(lambda d: jnp.zeros(d.shape, d.dtype), defs,
                         is_leaf=is_def)
    pages = {"kp": zeros["kp"], "vp": zeros["vp"]}
    # identity block table: position p lives in block p // bs
    bt = jnp.arange(num_blocks, dtype=jnp.int32)[None]          # (1, nb)

    # chunked prefill: [0, 8) full chunk, then [8, 13) padded to bucket 8
    logits = None
    pos = 0
    while pos < T:
        c = min(chunk, T - pos)
        toks = np.zeros((1, chunk), np.int32)
        toks[0, :c] = np.asarray(full["tokens"][0, pos: pos + c])
        pv = np.full((1, chunk), -1, np.int32)
        pv[0, :c] = np.arange(pos, pos + c)
        logits, pages = model.apply(
            params, {"tokens": jnp.asarray(toks),
                     "positions": jnp.asarray(pv),
                     "block_tables": bt,
                     "last_index": jnp.asarray([c - 1], jnp.int32)},
            mode="chunk_prefill", cache=pages)
        pos += c
    np.testing.assert_allclose(
        np.asarray(logits[:, 0], np.float32),
        np.asarray(ref_logits[:, T - 1], np.float32),
        rtol=2e-2, atol=2e-2)

    stack = lambda x: jnp.broadcast_to(x[None], (L,) + x.shape)
    for i in range(extra):
        cache = {"kp": pages["kp"], "vp": pages["vp"], "bt": stack(bt),
                 "len": stack(jnp.full((B,), T + i, jnp.int32))}
        dec_in = {"tokens": full["tokens"][:, T + i: T + i + 1],
                  "positions": jnp.full((B, 1), T + i, jnp.int32)}
        logits, cache = model.apply(params, dec_in, mode="decode",
                                    cache=cache)
        pages = {"kp": cache["kp"], "vp": cache["vp"]}
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(ref_logits[:, T + i], np.float32),
            rtol=2e-2, atol=2e-2)


def test_multi_step_decode_consistent():
    """Three consecutive decode steps match teacher forcing (dense arch)."""
    cfg = get_smoke_config("granite-20b")
    model = build_model(cfg)
    params = model.init(jax.random.key(2))
    B, T, extra = 2, 12, 3
    full = _batch(model, T + extra, B)
    ref_logits, _ = model.apply(params, full, mode="train")

    pre = {"tokens": full["tokens"][:, :T]}
    _, cache = model.apply(params, pre, mode="prefill")
    for i in range(extra):
        dec_in = {"tokens": full["tokens"][:, T + i: T + i + 1],
                  "positions": jnp.full((B, 1), T + i, jnp.int32)}
        logits, cache = model.apply(params, dec_in, mode="decode",
                                    cache=cache)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(ref_logits[:, T + i], np.float32),
            rtol=2e-2, atol=2e-2)
