"""Extra dist-layer coverage: randomized sharding-rule properties + HLO
cost-model goldens on hand-written fixtures (no compilation needed)."""
import numpy as np

from repro.dist import hlo_cost
from repro.dist.sharding import (DEFAULT_RULES, ISLAND_RULES, SERVE_RULES,
                                 abstract_mesh, logical_to_mesh_spec)

# ---------------------------------------------------------------------------
# Property: specs are always valid for random meshes / shapes / axes
# ---------------------------------------------------------------------------

LOGICAL = [None, "batch", "island", "embed", "embed_tp", "ffn", "expert_ffn",
           "heads", "kv_heads", "vocab", "experts", "ssm_inner", "lru_width",
           "layers", "unknown_axis"]
MESH_AXES = ["pod", "data", "model"]


def _spec_mesh_axes(spec):
    out = []
    for entry in spec:
        if entry is None:
            continue
        if isinstance(entry, tuple):
            out.extend(entry)
        else:
            out.append(entry)
    return out


def test_random_meshes_spec_always_valid():
    rng = np.random.default_rng(0)
    for _ in range(300):
        n_axes = int(rng.integers(1, 4))
        names = list(rng.choice(MESH_AXES, size=n_axes, replace=False))
        sizes = [int(rng.choice([1, 2, 3, 4, 8])) for _ in names]
        mesh = abstract_mesh(sizes, names)
        size_of = dict(zip(names, sizes))

        rank = int(rng.integers(1, 5))
        axes = tuple(rng.choice(LOGICAL, size=rank))
        axes = tuple(None if a == "None" else a for a in axes)
        shape = tuple(int(rng.choice([1, 2, 3, 6, 8, 16, 24, 64]))
                      for _ in range(rank))
        rules = [DEFAULT_RULES, ISLAND_RULES, SERVE_RULES][
            int(rng.integers(3))]
        spec = logical_to_mesh_spec(axes, shape, mesh, rules)

        used = _spec_mesh_axes(spec)
        # each mesh axis appears at most once across the whole spec
        assert len(used) == len(set(used)), (axes, shape, names, spec)
        # every used axis exists in the mesh
        assert all(u in size_of for u in used), (spec, names)
        # divisibility: the product of assigned axes divides the dim
        for dim, entry in zip(shape, spec):
            if entry is None:
                continue
            group = entry if isinstance(entry, tuple) else (entry,)
            prod = int(np.prod([size_of[a] for a in group]))
            assert dim % prod == 0, (axes, shape, names, spec)


def test_island_rules_never_put_batch_on_pod():
    rng = np.random.default_rng(1)
    mesh = abstract_mesh((2, 4, 8), ("pod", "data", "model"))
    for _ in range(50):
        b = int(rng.choice([2, 4, 8, 16, 64]))
        spec = logical_to_mesh_spec(("batch", None), (b, 5), mesh,
                                    ISLAND_RULES)
        assert "pod" not in _spec_mesh_axes(spec)


def test_serve_rules_keep_embed_replicated():
    mesh = abstract_mesh((4, 8), ("data", "model"))
    spec = logical_to_mesh_spec(("embed", "ffn"), (16, 64), mesh, SERVE_RULES)
    assert spec[0] is None and spec[1] == "model"


# ---------------------------------------------------------------------------
# HLO goldens (hand-written text: while loops, fusions, tuple roots)
# ---------------------------------------------------------------------------

def _while_module(attr: str, bound: str = "%n") -> str:
    return """
HloModule m

%body (p: (s32[], f32[16,16])) -> (s32[], f32[16,16]) {
  %p = (s32[], f32[16,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[16,16]{1,0}) %p), index=0
  %x = f32[16,16]{1,0} get-tuple-element((s32[], f32[16,16]{1,0}) %p), index=1
  %d = f32[16,16]{1,0} dot(f32[16,16]{1,0} %x, f32[16,16]{1,0} %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ip = s32[] add(s32[] %i, s32[] %one)
  ROOT %t = (s32[], f32[16,16]{1,0}) tuple(%ip, %d)
}

%cond (q: (s32[], f32[16,16])) -> pred[] {
  %q = (s32[], f32[16,16]{1,0}) parameter(0)
  %j = s32[] get-tuple-element((s32[], f32[16,16]{1,0}) %q), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(s32[] %j, BOUND), direction=LT
}

ENTRY %main (a: f32[16,16]) -> (s32[], f32[16,16]) {
  %a = f32[16,16]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[16,16]{1,0}) tuple(%z, %a)
  ROOT %w = (s32[], f32[16,16]{1,0}) while((s32[], f32[16,16]{1,0}) %t0), condition=%cond, body=%body ATTR
}
""".replace("ATTR", attr).replace("BOUND", f"s32[] {bound}")


def test_while_known_trip_count_multiplies():
    text = _while_module(
        ', backend_config={"known_trip_count":{"n":"5"}}')
    got = hlo_cost.analyze(text)
    assert got["diagnostics"] == []
    expect = 5 * 2 * 16 ** 3
    assert abs(got["flops"] - expect) / expect < 0.01


def test_while_trip_count_from_condition_constant():
    got = hlo_cost.analyze(_while_module(""))
    assert got["diagnostics"] == []
    expect = 5 * 2 * 16 ** 3
    assert abs(got["flops"] - expect) / expect < 0.01


def test_while_unknown_trip_count_diagnosed():
    # condition compares two loop-carried values: trip count is unknowable
    got = hlo_cost.analyze(_while_module("", bound="%j"))
    assert any("trip count" in d for d in got["diagnostics"])
    expect = 2 * 16 ** 3          # assumed 1 trip
    assert abs(got["flops"] - expect) / expect < 0.01


def test_fusion_dus_root_charges_window():
    text = """
HloModule m

%fused (fp0: f32[4096,512], fp1: f32[1,512], fp2: s32[]) -> f32[4096,512] {
  %fp0 = f32[4096,512]{1,0} parameter(0)
  %fp1 = f32[1,512]{1,0} parameter(1)
  %fp2 = s32[] parameter(2)
  ROOT %dus = f32[4096,512]{1,0} dynamic-update-slice(f32[4096,512]{1,0} %fp0, f32[1,512]{1,0} %fp1, s32[] %fp2, s32[] %fp2)
}

ENTRY %main (big: f32[4096,512], small: f32[1,512], i: s32[]) -> f32[4096,512] {
  %big = f32[4096,512]{1,0} parameter(0)
  %small = f32[1,512]{1,0} parameter(1)
  %i = s32[] parameter(2)
  ROOT %fu = f32[4096,512]{1,0} fusion(f32[4096,512]{1,0} %big, f32[1,512]{1,0} %small, s32[] %i), kind=kLoop, calls=%fused
}
"""
    got = hlo_cost.analyze(text)
    # window (2 KB) x2 + indices, NOT the 16 MB aliased big buffer
    assert got["hbm_bytes"] < 1e4, got["hbm_bytes"]


def test_tuple_root_entry_and_collectives_scale_with_trips():
    text = """
HloModule m

%body (p: (s32[], f32[1024])) -> (s32[], f32[1024]) {
  %p = (s32[], f32[1024]{0}) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[1024]{0}) %p), index=0
  %x = f32[1024]{0} get-tuple-element((s32[], f32[1024]{0}) %p), index=1
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), replica_groups={}
  %one = s32[] constant(1)
  %ip = s32[] add(s32[] %i, s32[] %one)
  ROOT %t = (s32[], f32[1024]{0}) tuple(%ip, %ar)
}

%cond (q: (s32[], f32[1024])) -> pred[] {
  %q = (s32[], f32[1024]{0}) parameter(0)
  %j = s32[] get-tuple-element((s32[], f32[1024]{0}) %q), index=0
  %n = s32[] constant(3)
  ROOT %lt = pred[] compare(s32[] %j, s32[] %n), direction=LT
}

ENTRY %main (a: f32[1024]) -> (s32[], f32[1024]) {
  %a = f32[1024]{0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[1024]{0}) tuple(%z, %a)
  %w = (s32[], f32[1024]{0}) while((s32[], f32[1024]{0}) %t0), condition=%cond, body=%body
  %r = f32[1024]{0} get-tuple-element((s32[], f32[1024]{0}) %w), index=1
  ROOT %out = (f32[1024]{0}, s32[]) tuple(%r, %z)
}
"""
    got = hlo_cost.analyze(text)
    # 3 trips x 4 KB all-reduce, attributed to the base opcode
    assert got["collective_bytes"] == 3 * 1024 * 4
    assert got["collective_by_op"] == {"all-reduce": 3 * 1024 * 4}
    mc = hlo_cost.ModuleCost(text)
    root = mc.comps["main"].root
    assert root.opcode == "tuple" and root.is_root


def test_tuple_types_with_multidim_leaves_and_layouts():
    """Commas inside dims [128,128] / layouts {1,0} must not split the
    tuple (regression: paren-only depth tracking zero-costed async
    collectives and fusion tuple roots)."""
    got = hlo_cost.parse_shape("(f32[128,128]{1,0}, bf16[64,2,2], s32[])")
    assert got == [("f32", [128, 128]), ("bf16", [64, 2, 2]), ("s32", [])]
    assert hlo_cost.leaf_bytes(got) == 128 * 128 * 4 + 64 * 2 * 2 * 2 + 4

    from repro.dist.hlo_analysis import collective_bytes
    fake = ("  %ar = (f32[128,128]{1,0}, f32[128,128]{1,0}) "
            "all-reduce-start(f32[128,128]{1,0} %x), replica_groups={}\n"
            "  %d = f32[128,128]{1,0} all-reduce-done((f32[128,128]{1,0}, "
            "f32[128,128]{1,0}) %ar)\n")
    got = collective_bytes(fake)
    assert got["count"] == 1
    assert got["by_op"]["all-reduce"] == 2 * 128 * 128 * 4
