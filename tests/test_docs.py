"""The docs lint (scripts/check_docs.py) must stay green in tier-1 too:
broken relative links and undocumented dist modules fail here, not just in
the CI docs job."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "scripts"))

import check_docs


def test_relative_links_resolve():
    problems = []
    for name in check_docs.DOCS:
        doc = check_docs.ROOT / name
        if doc.exists():
            problems += check_docs.check_links(doc)
    assert problems == []


def test_dist_modules_have_docstrings():
    problems = []
    for rel in check_docs.DOCSTRING_ROOTS:
        problems += check_docs.check_docstrings(check_docs.ROOT / rel)
    assert problems == []
