"""Integration tests for the discrete-event FL engine (Tier A)."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.client import LocalTrainer, SimWorker
from repro.core.cost_model import heterogeneous_profiles, make_stats
from repro.core.events import FLSimulation
from repro.core.server import AggregationServer, ServerConfig
from repro.data.partition import partition_by_batches
from repro.models import build_model
from repro.models.config import ModelConfig

MLP = ModelConfig(name="tiny-mlp", family="cnn", num_layers=0, d_model=64,
                  img_hw=28, img_c=1, n_classes=10, remat=False)


def make_sim(synmnist, synmnist_test, *, n_workers=4, policy="all",
             mode="sync", batches=None, seed=0, epochs=2):
    imgs, labels = synmnist
    model = build_model(MLP)
    trainer = LocalTrainer(model, lr=0.05, batch_size=64)
    batches = batches or [4] * n_workers
    shards = partition_by_batches(imgs, labels, batches, batch_size=64,
                                  seed=seed)
    profiles = heterogeneous_profiles(n_workers,
                                      [s[0].shape[0] for s in shards],
                                      seed=seed)
    import jax
    params = model.init(jax.random.key(seed))
    workers, stats = {}, {}
    model_bytes = 4 * sum(np.prod(l.shape) for l in
                          jax.tree.leaves(params))
    for i, (p, (xi, yi)) in enumerate(zip(profiles, shards)):
        workers[i] = SimWorker(i, xi, yi, trainer, p)
        stats[i] = make_stats(p, t_onedata_server=5e-5, server_freq=2.4e9,
                              model_bytes=int(model_bytes))
    srv = AggregationServer(params, stats,
                            ServerConfig(policy=policy, mode=mode,
                                         epochs_per_round=epochs), seed=seed)
    ti, tl = synmnist_test
    return FLSimulation(srv, workers, ti[:512], tl[:512],
                        t_per_sample_ref=5e-5,
                        model_bytes=int(model_bytes), seed=seed)


def test_sync_learns(synmnist, synmnist_test):
    sim = make_sim(synmnist, synmnist_test)
    res = sim.run_sync(rounds=6)
    assert res.best_acc > 0.5
    # time strictly increases
    times = [r.time for r in res.records]
    assert all(b > a for a, b in zip(times, times[1:]))


def test_sync_deterministic(synmnist, synmnist_test):
    r1 = make_sim(synmnist, synmnist_test, seed=3).run_sync(rounds=3)
    r2 = make_sim(synmnist, synmnist_test, seed=3).run_sync(rounds=3)
    assert [(a.time, a.acc) for a in r1.records] == \
        [(b.time, b.acc) for b in r2.records]


def test_same_seed_identical_simrecords_sync_and_async(synmnist,
                                                       synmnist_test):
    """Full SimRecord-sequence equality (every field), both engines.
    Guards the async heap's `seq` tie-break and the RNG threading through
    the vmapped cohort path (keys are drawn per worker in plan order)."""
    s1 = make_sim(synmnist, synmnist_test, n_workers=5, seed=9,
                  batches=[2] * 5).run_sync(rounds=4)
    s2 = make_sim(synmnist, synmnist_test, n_workers=5, seed=9,
                  batches=[2] * 5).run_sync(rounds=4)
    assert s1.records == s2.records
    a1 = make_sim(synmnist, synmnist_test, n_workers=5, mode="async", seed=9,
                  batches=[2] * 5).run_async(max_merges=10)
    a2 = make_sim(synmnist, synmnist_test, n_workers=5, mode="async", seed=9,
                  batches=[2] * 5).run_async(max_merges=10)
    assert a1.records == a2.records


def test_async_learns_and_merges_one_at_a_time(synmnist, synmnist_test):
    sim = make_sim(synmnist, synmnist_test, mode="async")
    res = sim.run_async(max_merges=48)
    assert res.best_acc > 0.5
    assert all(r.n_selected <= 1 for r in res.records[1:])


def test_async_faster_than_sync_on_heterogeneous_fleet(synmnist,
                                                       synmnist_test):
    """The paper's headline: async reaches target accuracy sooner because
    fast workers never wait for stragglers."""
    target = 0.55
    sync = make_sim(synmnist, synmnist_test, n_workers=6,
                    batches=[2, 2, 2, 2, 2, 2]).run_sync(
        rounds=14, target_acc=target)
    asyn = make_sim(synmnist, synmnist_test, n_workers=6, mode="async",
                    batches=[2, 2, 2, 2, 2, 2]).run_async(
        max_merges=120, target_acc=target)
    t_sync = sync.time_to_accuracy(target)
    t_async = asyn.time_to_accuracy(target)
    assert t_async < t_sync, (t_async, t_sync)


def test_alg2_selects_subset_and_learns(synmnist, synmnist_test):
    sim = make_sim(synmnist, synmnist_test, n_workers=6,
                   policy="time_based", batches=[2] * 6)
    res = sim.run_sync(rounds=18)
    # the point is subset selection + learning progress, not the absolute
    # level (the pool admits workers only on accuracy stalls)
    assert res.best_acc > 0.3
    n_sel = [r.n_selected for r in res.records]
    assert n_sel[1] <= 1  # cold start: T=0 admits nobody (or first only)
    assert max(n_sel) >= 1


def test_worker_failure_is_survived(synmnist, synmnist_test):
    """Fault tolerance: killing a worker mid-run must not stop training --
    FL treats it as an unselected/late worker (DESIGN.md SS7)."""
    sim = make_sim(synmnist, synmnist_test, n_workers=4, mode="async")
    res1 = sim.run_async(max_merges=12)
    dead = max(sim.server.stats)
    del sim.server.stats[dead]           # server no longer selects it
    res2 = sim.run_async(max_merges=12)
    assert res2.best_acc >= 0.9 * res1.best_acc - 0.05
