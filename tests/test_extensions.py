"""Beyond-paper extensions: FedOpt server optimizers, utility selection,
elastic island rescale on resume."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import federated as fed
from repro.core.cost_model import WorkerStats
from repro.core.selection import select_utility
from repro.core.server_opt import ServerOptimizer


def trees(k, seed=0):
    rng = np.random.default_rng(seed)
    return [{"w": jnp.asarray(rng.normal(size=(6,)), jnp.float32)}
            for _ in range(k)]


# ---------------- FedOpt server optimizers ----------------

def test_server_avg_matches_weighted_average():
    from repro.core.aggregation import weighted_average
    opt = ServerOptimizer("avg")
    ts = trees(3)
    st = opt.init(ts[0])
    new, _ = opt.apply(ts[0], ts, [0.2, 0.3, 0.5], st)
    want = weighted_average(ts, [0.2, 0.3, 0.5])
    np.testing.assert_allclose(np.asarray(new["w"]), np.asarray(want["w"]),
                               rtol=1e-6)


@pytest.mark.parametrize("method", ["avgm", "adam", "yogi"])
def test_server_opt_moves_toward_worker_consensus(method):
    opt = ServerOptimizer(method, lr=0.5)
    server = {"w": jnp.zeros((6,), jnp.float32)}
    target = {"w": jnp.ones((6,), jnp.float32)}
    st = opt.init(server)
    d0 = float(jnp.abs(server["w"] - target["w"]).mean())
    for _ in range(30):
        server, st = opt.apply(server, [target, target], [0.5, 0.5], st)
    d1 = float(jnp.abs(server["w"] - target["w"]).mean())
    assert d1 < 0.2 * d0, (method, d0, d1)


def test_server_opt_state_shapes():
    opt = ServerOptimizer("adam")
    st = opt.init({"w": jnp.zeros((4, 2), jnp.bfloat16)})
    assert st.momentum["w"].shape == (4, 2)
    assert st.momentum["w"].dtype == jnp.float32


# ---------------- utility (Oort-style) selection ----------------

def _stats(t_ones, n_data):
    return {i: WorkerStats(i, t, 0.1, n)
            for i, (t, n) in enumerate(zip(t_ones, n_data))}


def test_utility_selection_prefers_useful_workers():
    s = _stats([1.0, 1.0, 1.0, 10.0], [100, 100, 100, 100])
    util = {0: 0.1, 1: 5.0, 2: 0.1, 3: 5.0}  # 1 useful+fast; 3 useful+slow
    sel = select_utility(s, 2, utilities=util, explore=0.0)
    # useful workers beat useless ones, even a slow useful one (Oort's
    # statistical-utility tradeoff); the fast useful worker ranks first
    assert sel == [1, 3]
    # with k=1 only the fast useful worker survives
    assert select_utility(s, 1, utilities=util, explore=0.0) == [1]


def test_utility_selection_explores():
    s = _stats([1.0] * 10, [10] * 10)
    util = {i: (10.0 if i == 0 else 0.01) for i in range(10)}
    rng = np.random.default_rng(0)
    picks = set()
    for _ in range(20):
        picks.update(select_utility(s, 3, utilities=util, explore=0.5,
                                    rng=rng))
    assert len(picks) > 4  # exploration reaches beyond the top utilities


def test_utility_selection_k_bounds():
    s = _stats([1.0, 2.0], [1, 1])
    assert len(select_utility(s, 5, utilities={})) == 2
    assert select_utility({}, 3, utilities={}) == []


# ---------------- elastic island rescale on resume ----------------

def test_elastic_island_rescale_roundtrip(tmp_path):
    """Checkpoint written with 2 islands restores onto 4 (and back to 1):
    the FL aggregate is the natural consolidation point (DESIGN.md SS7)."""
    from repro.checkpoint import CheckpointManager

    params = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(8,)),
                               jnp.float32)}
    stacked2 = fed.stack_islands(params, 2)
    # islands diverge a little
    stacked2 = jax.tree.map(
        lambda x: x + jnp.arange(2, dtype=jnp.float32)[:, None], stacked2)

    mgr = CheckpointManager(tmp_path)
    # consolidate-then-save: one sync exchange makes islands identical
    M = jnp.asarray(fed.selection_mixing(np.array([0.5, 0.5]), np.ones(2)),
                    jnp.float32)
    consolidated = fed.fl_aggregate(stacked2, M)
    mgr.save(7, params=fed.island_slice(consolidated, 0),
             extra={"islands_at_save": 2})

    # restore to FOUR islands
    _, restored, _, _ = mgr.restore(params_like=params)
    stacked4 = fed.stack_islands(jax.tree.map(jnp.asarray, restored), 4)
    assert stacked4["w"].shape == (4, 8)
    for i in range(4):
        np.testing.assert_allclose(
            np.asarray(stacked4["w"][i]),
            np.asarray(consolidated["w"][0]), rtol=1e-6)

    # restore to ONE island (shrink): same weights, no conversion tools
    _, restored1, _, _ = mgr.restore(params_like=params)
    np.testing.assert_allclose(np.asarray(restored1["w"]),
                               np.asarray(consolidated["w"][0]), rtol=1e-6)
