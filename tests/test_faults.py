"""Fault injection (core/faults.py) + the server's sanitization gate.

  * a FaultPlan is a pure function of (seed, wid, round): replayable and
    call-order independent;
  * corruption semantics per attack (sign_flip reflection, scale blow-up,
    nan/inf spray, stale-base replay);
  * no injected NaN/Inf ever reaches the published server model -- the
    gate rejects it and quarantined repeat offenders stop being selected;
  * async retry/backoff policy is bounded and doubling;
  * under a sign-flip+scale attack the robust fold beats plain FedAvg
    (scenario engine, small scale).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation
from repro.core.cost_model import WorkerStats
from repro.core.faults import ATTACKS, FaultConfig, FaultPlan, finite_members
from repro.core.server import AggregationServer, ServerConfig

PARAMS = {"w": jnp.ones((4, 3), jnp.float32), "b": jnp.zeros(5, jnp.float32)}
BASE = {"w": jnp.full((4, 3), 0.5, jnp.float32),
        "b": jnp.full((5,), -0.5, jnp.float32)}


def plan(**kw):
    return FaultPlan(FaultConfig(**kw))


# ---- determinism / replayability ----------------------------------------

def test_plan_is_replayable_and_order_independent():
    a = plan(byzantine_frac=0.3, drop_frac=0.2, duplicate_frac=0.1, seed=5)
    b = plan(byzantine_frac=0.3, drop_frac=0.2, duplicate_frac=0.1, seed=5)
    # query b in reverse order: decisions must not depend on call order
    fwd = [(a.is_byzantine(w), a.attack_for(w), a.response_fate(w, r))
           for w in range(20) for r in range(5)]
    rev = [(b.is_byzantine(w), b.attack_for(w), b.response_fate(w, r))
           for w in reversed(range(20)) for r in reversed(range(5))]
    assert fwd == list(reversed(rev))


def test_different_seeds_differ():
    marks = [tuple(plan(byzantine_frac=0.5, seed=s).is_byzantine(w)
                   for w in range(32)) for s in range(4)]
    assert len(set(marks)) > 1


def test_corrupt_is_identity_for_honest_workers():
    p = plan(byzantine_frac=0.0)
    out = p.corrupt(PARAMS, BASE, wid=1, rnd=0)
    assert out is PARAMS


def _corrupted(attack, **kw):
    p = plan(byzantine_frac=1.0, attacks=(attack,), **kw)
    assert p.is_byzantine(3)
    return p.corrupt(PARAMS, BASE, wid=3, rnd=2)


def test_sign_flip_reflects_the_delta():
    out = _corrupted("sign_flip")
    np.testing.assert_allclose(
        np.asarray(out["w"]), 2 * np.asarray(BASE["w"])
        - np.asarray(PARAMS["w"]), rtol=1e-6)


def test_scale_blows_up_the_delta():
    out = _corrupted("scale", scale_factor=7.0)
    want = np.asarray(BASE["w"]) + 7.0 * (np.asarray(PARAMS["w"])
                                          - np.asarray(BASE["w"]))
    np.testing.assert_allclose(np.asarray(out["w"]), want, rtol=1e-6)


@pytest.mark.parametrize("attack", ["nan", "inf"])
def test_nonfinite_attacks_poison_at_least_one_entry(attack):
    out = _corrupted(attack)
    assert not aggregation.tree_finite(out)
    # replay injects the identical mask
    again = _corrupted(attack)
    np.testing.assert_array_equal(
        np.isfinite(np.asarray(out["w"])),
        np.isfinite(np.asarray(again["w"])))


def test_stale_replays_the_dispatch_base():
    out = _corrupted("stale")
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(BASE["w"]))


def test_unknown_attack_rejected():
    with pytest.raises(ValueError):
        plan(attacks=("gradient_surgery",))
    assert set(ATTACKS) >= {"nan", "inf", "sign_flip", "scale"}


def test_corrupt_stacked_matches_per_member_corrupt():
    p = plan(byzantine_frac=0.5, attacks=("sign_flip",), seed=9)
    stacked = jax.tree.map(
        lambda l: jnp.stack([l * (i + 1) for i in range(4)]), PARAMS)
    wids = [10, 11, 12, 13]
    out = p.corrupt_stacked(stacked, BASE, wids, rnd=1)
    for i, w in enumerate(wids):
        member = jax.tree.map(lambda l: l[i], stacked)
        want = p.corrupt(member, BASE, w, 1)
        np.testing.assert_allclose(
            np.asarray(jax.tree.leaves(jax.tree.map(lambda l: l[i], out))[0]),
            np.asarray(jax.tree.leaves(want)[0]), rtol=1e-6)


def test_finite_members_flags_only_bad_slices():
    stacked = jax.tree.map(lambda l: jnp.stack([l] * 3), PARAMS)
    stacked["w"] = stacked["w"].at[1, 0, 0].set(jnp.nan)
    np.testing.assert_array_equal(finite_members(stacked),
                                  [True, False, True])


def test_server_crash_schedule():
    p = plan(server_crash_rounds=(3, 7))
    assert [r for r in range(10) if p.server_crashes(r)] == [3, 7]


# ---- the server-side gate ------------------------------------------------

def make_server(**cfg_kw):
    stats = {w: WorkerStats(wid=w, t_one=0.1, t_transmit=0.05, n_data=64)
             for w in range(4)}
    return AggregationServer(
        {"w": jnp.zeros((3, 2), jnp.float32)}, stats,
        ServerConfig(policy="all", **cfg_kw), seed=0)


def test_sanitize_sync_rejects_nonfinite_and_outliers():
    srv = make_server(norm_outlier_mult=3.0)
    good = {"w": jnp.full((3, 2), 0.1, jnp.float32)}
    responses = {0: good, 1: good,
                 2: {"w": jnp.full((3, 2), jnp.nan)},
                 3: {"w": jnp.full((3, 2), 1e4, jnp.float32)}}
    out = srv.sanitize_sync(responses)
    assert sorted(out) == [0, 1]
    assert srv.quarantine == {2: 1, 3: 1}
    assert [w for _, w, _ in srv.rejections] == [2, 3]


def test_no_injected_nonfinite_reaches_published_model():
    srv = make_server()
    poisoned = {0: {"w": jnp.full((3, 2), 0.1, jnp.float32)},
                1: {"w": jnp.full((3, 2), jnp.inf)}}
    srv.sync_aggregate(poisoned, sim_time=1.0)
    assert aggregation.tree_finite(srv.params)
    assert not srv.async_fold(1, {"w": jnp.full((3, 2), jnp.nan)}, 0, 2.0)
    assert aggregation.tree_finite(srv.params)


def test_quarantined_workers_leave_the_selection_pool():
    srv = make_server(quarantine_threshold=2)
    assert sorted(srv.select()) == [0, 1, 2, 3]
    srv.note_divergence(2)
    assert sorted(srv.select()) == [0, 1, 2, 3]   # one strike: still in
    srv.note_divergence(2)
    assert sorted(srv.select()) == [0, 1, 3]      # benched at threshold


def test_retry_policy_is_bounded_and_doubling():
    srv = make_server(max_retries=3, retry_backoff=0.5)
    assert [srv.retry_policy(0, n) for n in (1, 2, 3)] == [0.5, 1.0, 2.0]
    assert srv.retry_policy(0, 4) is None         # bounded
    for _ in range(srv.cfg.quarantine_threshold):
        srv.note_divergence(1)
    assert srv.retry_policy(1, 1) is None         # quarantined: no retry


def test_async_ewma_norm_gate():
    srv = make_server(norm_outlier_mult=2.0)
    small = {"w": jnp.full((3, 2), 0.01, jnp.float32)}
    assert srv.sanitize_async(0, small)           # seeds the EWMA
    assert not srv.sanitize_async(1, {"w": jnp.full((3, 2), 50.0,
                                                    jnp.float32)})
    assert srv.quarantine.get(1) == 1


# ---- end-to-end: robust fold beats plain FedAvg under attack -------------

def test_robust_beats_fedavg_under_attack():
    from repro.core.scenarios import ScenarioConfig, ScenarioSim
    base = dict(n_workers=120, cohort_size=10, fog_cells=1,
                participation=0.25, samples_per_worker=96, epochs=2,
                byzantine_frac=0.2, byzantine_scale=10.0, seed=3)
    attacked = ScenarioSim(ScenarioConfig(**base), pool=1024,
                           eval_n=256).run_sync(8)
    robust = ScenarioSim(ScenarioConfig(**base, robust_agg="trimmed_mean",
                                        trim_frac=0.3), pool=1024,
                         eval_n=256).run_sync(8)
    assert robust.best_acc >= attacked.best_acc
    assert aggregation.tree_finite(robust.final_params)


def test_scenario_nan_attack_never_reaches_model():
    from repro.core.scenarios import ScenarioConfig, ScenarioSim
    cfg = ScenarioConfig(n_workers=40, cohort_size=6, fog_cells=2,
                         participation=0.4, samples_per_worker=32,
                         byzantine_frac=0.5,
                         byzantine_attacks=("nan", "inf"), seed=1)
    sim = ScenarioSim(cfg, pool=256, eval_n=128)
    res = sim.run_sync(3)
    assert aggregation.tree_finite(res.final_params)
    assert sim.quarantine                         # rejections were counted
