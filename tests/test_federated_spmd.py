"""Tier-B federated SPMD: island mixing, selection, compressed exchange."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import federated as fed


def stacked(P=4, seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(P, 16)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(P, 3, 5)), jnp.bfloat16)}


def test_stack_and_slice_roundtrip():
    tree = {"w": jnp.arange(6.0).reshape(2, 3)}
    st = fed.stack_islands(tree, 3)
    assert st["w"].shape == (3, 2, 3)
    out = fed.island_slice(st, 2)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))


def test_sync_aggregate_consensus_under_jit():
    P = 4
    sp = stacked(P)
    w = np.full(P, 1.0 / P)
    M = jnp.asarray(fed.selection_mixing(w, np.ones(P)), jnp.float32)
    out = jax.jit(fed.fl_aggregate)(sp, M)
    arr = np.asarray(out["w"])
    for i in range(1, P):
        np.testing.assert_allclose(arr[i], arr[0], rtol=1e-6)


def test_selection_zeroes_unselected_contributions():
    P = 3
    sp = stacked(P)
    sel = np.array([1.0, 0.0, 1.0])
    M = fed.selection_mixing(np.full(P, 1 / 3), sel)
    out = fed.fl_aggregate(sp, jnp.asarray(M, jnp.float32))
    want = (np.asarray(sp["w"])[0] + np.asarray(sp["w"])[2]) / 2
    np.testing.assert_allclose(np.asarray(out["w"])[1], want, rtol=1e-6)


def test_nobody_selected_is_identity():
    P = 3
    sp = stacked(P)
    M = fed.selection_mixing(np.full(P, 1 / 3), np.zeros(P))
    out = fed.fl_aggregate(sp, jnp.asarray(M, jnp.float32))
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(sp["w"]),
                               rtol=1e-6)


def test_async_mixing_partial_fold():
    P = 2
    sp = {"w": jnp.asarray([[0.0, 0.0], [10.0, 10.0]], jnp.float32)}
    # island 0 folds 50% of island 1; island 1 unchanged
    M = fed.async_mixing(np.array([0.5, 0.0]), np.array([0.0, 1.0]))
    out = fed.fl_aggregate(sp, jnp.asarray(M, jnp.float32))
    np.testing.assert_allclose(np.asarray(out["w"]),
                               [[5.0, 5.0], [10.0, 10.0]], rtol=1e-6)


def test_compressed_aggregate_close_to_exact():
    P = 4
    sp = stacked(P, seed=3)
    M = jnp.asarray(fed.selection_mixing(np.full(P, 1 / P), np.ones(P)),
                    jnp.float32)
    exact = fed.fl_aggregate(sp, M)
    base = jax.tree.map(lambda x: jnp.broadcast_to(x[:1], x.shape), sp)
    approx = fed.fl_aggregate_compressed(sp, base, M)
    for a, b in zip(jax.tree.leaves(exact), jax.tree.leaves(approx)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=0.08, atol=0.08)


def test_island_clock_straggler_selection():
    c = fed.IslandClock(4)
    c.observe(np.array([1.0, 1.1, 0.9, 5.0]))
    sel = c.selection(slack=1.5)
    np.testing.assert_array_equal(sel, [1.0, 1.0, 1.0, 0.0])
    # before any observation: everyone selected
    assert fed.IslandClock(3).selection().sum() == 3
