"""Tier-B federated SPMD: island mixing, selection, compressed exchange."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import federated as fed


def stacked(P=4, seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(P, 16)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(P, 3, 5)), jnp.bfloat16)}


def test_stack_and_slice_roundtrip():
    tree = {"w": jnp.arange(6.0).reshape(2, 3)}
    st = fed.stack_islands(tree, 3)
    assert st["w"].shape == (3, 2, 3)
    out = fed.island_slice(st, 2)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))


def test_sync_aggregate_consensus_under_jit():
    P = 4
    sp = stacked(P)
    w = np.full(P, 1.0 / P)
    M = jnp.asarray(fed.selection_mixing(w, np.ones(P)), jnp.float32)
    out = jax.jit(fed.fl_aggregate)(sp, M)
    arr = np.asarray(out["w"])
    for i in range(1, P):
        np.testing.assert_allclose(arr[i], arr[0], rtol=1e-6)


def test_selection_zeroes_unselected_contributions():
    P = 3
    sp = stacked(P)
    sel = np.array([1.0, 0.0, 1.0])
    M = fed.selection_mixing(np.full(P, 1 / 3), sel)
    out = fed.fl_aggregate(sp, jnp.asarray(M, jnp.float32))
    want = (np.asarray(sp["w"])[0] + np.asarray(sp["w"])[2]) / 2
    np.testing.assert_allclose(np.asarray(out["w"])[1], want, rtol=1e-6)


def test_nobody_selected_is_identity():
    P = 3
    sp = stacked(P)
    M = fed.selection_mixing(np.full(P, 1 / 3), np.zeros(P))
    out = fed.fl_aggregate(sp, jnp.asarray(M, jnp.float32))
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(sp["w"]),
                               rtol=1e-6)


def test_async_mixing_partial_fold():
    P = 2
    sp = {"w": jnp.asarray([[0.0, 0.0], [10.0, 10.0]], jnp.float32)}
    # island 0 folds 50% of island 1; island 1 unchanged
    M = fed.async_mixing(np.array([0.5, 0.0]), np.array([0.0, 1.0]))
    out = fed.fl_aggregate(sp, jnp.asarray(M, jnp.float32))
    np.testing.assert_allclose(np.asarray(out["w"]),
                               [[5.0, 5.0], [10.0, 10.0]], rtol=1e-6)


def test_compressed_aggregate_close_to_exact():
    P = 4
    sp = stacked(P, seed=3)
    M = jnp.asarray(fed.selection_mixing(np.full(P, 1 / P), np.ones(P)),
                    jnp.float32)
    exact = fed.fl_aggregate(sp, M)
    base = jax.tree.map(lambda x: jnp.broadcast_to(x[:1], x.shape), sp)
    approx = fed.fl_aggregate_compressed(sp, base, M)
    for a, b in zip(jax.tree.leaves(exact), jax.tree.leaves(approx)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=0.08, atol=0.08)


def _mixing(P):
    return jnp.asarray(fed.selection_mixing(np.full(P, 1 / P), np.ones(P)),
                       jnp.float32)


def test_compressed_dispatches_through_quant8_kernels(monkeypatch):
    """The TPU path (impl="pallas"; interpret off-TPU) must quantise
    through kernels/quant8, not the inline jnp re-implementation."""
    from repro.kernels.quant8 import ops as q8ops
    calls = []
    real = q8ops.quantize_rowwise

    def spy(x, **kw):
        calls.append(x.shape)
        return real(x, **kw)

    monkeypatch.setattr(q8ops, "quantize_rowwise", spy)
    P = 4
    sp = stacked(P, seed=5)
    base = jax.tree.map(lambda x: jnp.broadcast_to(x[:1], x.shape), sp)
    ref = fed.fl_aggregate_compressed(sp, base, _mixing(P), impl="ref")
    assert calls == []                      # jnp fallback never touches it
    pal = fed.fl_aggregate_compressed(sp, base, _mixing(P), impl="pallas")
    assert len(calls) == len(jax.tree.leaves(sp))
    # acceptance: fused exchange parity vs jnp reference <= 1e-2 max-abs
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(pal)):
        err = np.abs(np.asarray(a, np.float32)
                     - np.asarray(b, np.float32)).max()
        assert err <= 1e-2


@pytest.mark.parametrize("mode", ["q8", "topk", "q8_topk"])
def test_compressed_modes_close_to_exact(mode):
    P = 4
    sp = stacked(P, seed=3)
    base = jax.tree.map(lambda x: jnp.broadcast_to(x[:1], x.shape), sp)
    exact = fed.fl_aggregate(sp, _mixing(P))
    # k_frac=1.0 keeps everything: topk must then be harmless
    approx = fed.fl_aggregate_compressed(sp, base, _mixing(P), mode=mode,
                                         k_frac=1.0)
    for a, b in zip(jax.tree.leaves(exact), jax.tree.leaves(approx)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=0.08, atol=0.08)


def test_topk_aggregate_moves_only_large_coordinates():
    P = 2
    base = {"w": jnp.zeros((P, 8), jnp.float32)}
    delta = np.zeros((P, 8), np.float32)
    delta[:, 0] = 4.0          # the one big coordinate per island
    delta[:, 1:] = 0.01
    sp = {"w": jnp.asarray(delta)}
    out = fed.fl_aggregate_compressed(sp, base, _mixing(P), mode="topk",
                                      k_frac=1 / 8)
    got = np.asarray(out["w"])
    np.testing.assert_allclose(got[:, 0], 4.0, rtol=1e-6)
    np.testing.assert_allclose(got[:, 1:], 0.0)   # small coords dropped


def test_compressed_zero_delta_is_identity():
    """No island moved -> scale clamp path -> output == base exactly."""
    P = 3
    base = stacked(P, seed=9)
    out = fed.fl_aggregate_compressed(base, base, _mixing(P), mode="q8")
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(base)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_overlap_merge_carries_local_progress():
    """fl_overlap_merge(params, mixed, snapshot) == mixed + (params -
    snapshot): the local step taken while the collective flew survives."""
    P = 2
    snap = stacked(P, seed=11)
    mixed = fed.fl_aggregate(snap, _mixing(P))
    progress = jax.tree.map(lambda x: (x.astype(jnp.float32) + 0.5
                                       ).astype(x.dtype), snap)
    merged = fed.fl_overlap_merge(progress, mixed, snap)
    for m, x in zip(jax.tree.leaves(merged), jax.tree.leaves(mixed)):
        np.testing.assert_allclose(np.asarray(m, np.float32),
                                   np.asarray(x, np.float32) + 0.5,
                                   rtol=1e-2, atol=1e-2)


def test_island_clock_straggler_selection():
    c = fed.IslandClock(4)
    c.observe(np.array([1.0, 1.1, 0.9, 5.0]))
    sel = c.selection(slack=1.5)
    np.testing.assert_array_equal(sel, [1.0, 1.0, 1.0, 0.0])
    # before any observation: everyone selected
    assert fed.IslandClock(3).selection().sum() == 3
