"""Proof-by-test that edge->fog->cloud aggregation == flat aggregation.

The whole point of the fog tier is that it is a pure scaling move: for
matching weights the two-tier composition must match the single flat
`fl_aggregate` (sync FedAvg) and the staleness-weighted async fold, to
<= 1e-5.  These tests pin that identity at the matrix level, the pytree
level, and end-to-end through the discrete-event simulator.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation, federated, hierarchy

TOL = dict(rtol=1e-5, atol=1e-5)


def random_stacked(P, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(P, 6, 5)), dtype),
        "b": jnp.asarray(rng.normal(size=(P, 7)), dtype),
    }


def random_weights_cells(P, K, seed=0):
    rng = np.random.default_rng(seed + 100)
    weights = rng.uniform(0.1, 5.0, P)
    cell_of = rng.integers(0, K, P)
    cell_of[:K] = np.arange(K)  # every cell non-empty
    return weights, cell_of


# -- matrix level ----------------------------------------------------------

@pytest.mark.parametrize("P,K", [(6, 1), (6, 2), (8, 3), (12, 12), (5, 4)])
def test_matrix_composition_equals_flat(P, K):
    weights, cell_of = random_weights_cells(P, K, seed=P * 31 + K)
    edge = hierarchy.edge_mixing_matrix(weights, cell_of)
    cloud = hierarchy.cloud_mixing_matrix(weights, cell_of)
    flat = hierarchy.flat_mixing_matrix(weights)
    # both stages are row-stochastic
    np.testing.assert_allclose(edge.sum(axis=1), 1.0, **TOL)
    np.testing.assert_allclose(cloud.sum(axis=1), 1.0, **TOL)
    # and their composition IS the flat mixing
    np.testing.assert_allclose(cloud @ edge, flat, **TOL)


def test_edge_matrix_is_block_diagonal():
    weights, cell_of = random_weights_cells(8, 3, seed=1)
    edge = hierarchy.edge_mixing_matrix(weights, cell_of)
    for i in range(8):
        for j in range(8):
            if cell_of[i] != cell_of[j]:
                assert edge[i, j] == 0.0


# -- pytree level (fl_aggregate two hops) ----------------------------------

@pytest.mark.parametrize("P,K", [(6, 2), (9, 3), (7, 7), (6, 1)])
def test_hierarchical_sync_aggregate_equals_flat(P, K):
    stacked = random_stacked(P, seed=P + K)
    weights, cell_of = random_weights_cells(P, K, seed=P + K)
    flat = federated.fl_aggregate(
        stacked, jnp.asarray(hierarchy.flat_mixing_matrix(weights),
                             jnp.float32))
    hier = hierarchy.hierarchical_sync_aggregate(stacked, weights, cell_of)
    for a, b in zip(jax.tree.leaves(flat), jax.tree.leaves(hier)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL)


@pytest.mark.parametrize("P,K", [(6, 2), (8, 4)])
def test_hierarchical_async_aggregate_equals_flat(P, K):
    """The staleness-weighted case: island i keeps (1 - a_i) of itself and
    takes a_i of the contributor mix; contributions fold through the fog
    tier first."""
    stacked = random_stacked(P, seed=17 + P)
    rng = np.random.default_rng(5 + P)
    alphas = rng.uniform(0.0, 0.9, P)
    contributors = rng.uniform(0.0, 2.0, P)
    contributors[rng.integers(0, P)] = 0.0       # someone contributed nothing
    _, cell_of = random_weights_cells(P, K, seed=3 + P)
    flat = federated.fl_aggregate(
        stacked, jnp.asarray(
            aggregation.async_mixing_matrix(alphas, contributors),
            jnp.float32))
    hier = hierarchy.hierarchical_async_aggregate(stacked, alphas,
                                                  contributors, cell_of)
    for a, b in zip(jax.tree.leaves(flat), jax.tree.leaves(hier)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL)


@pytest.mark.parametrize("compress", ["q8", "topk", "q8_topk"])
def test_hierarchical_compressed_close_to_flat(compress):
    """Compressed fog exchange: the edge hop mixes cell-locally (block-
    diagonal mixing), so only the compressed delta crosses the cloud hop.
    With k_frac=1.0 top-k keeps everything, leaving only int8 rounding
    between the compressed two-tier path and the exact flat mixing."""
    P, K = 6, 2
    stacked = random_stacked(P, seed=P + K)
    weights, cell_of = random_weights_cells(P, K, seed=P + K)
    base = jax.tree.map(lambda x: jnp.broadcast_to(x[:1], x.shape), stacked)
    flat = federated.fl_aggregate(
        stacked, jnp.asarray(hierarchy.flat_mixing_matrix(weights),
                             jnp.float32))
    hier = hierarchy.hierarchical_sync_aggregate(
        stacked, weights, cell_of, compress=compress, base_params=base,
        k_frac=1.0)
    for a, b in zip(jax.tree.leaves(flat), jax.tree.leaves(hier)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0.1, atol=0.1)
    with pytest.raises(ValueError):
        hierarchy.hierarchical_sync_aggregate(stacked, weights, cell_of,
                                              compress="q8")


# -- dict level (Tier A responses) ----------------------------------------

def test_fog_aggregate_responses_equals_flat():
    rng = np.random.default_rng(0)
    wids = [3, 5, 9, 11, 20, 21]
    responses = {w: {"p": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)}
                 for w in wids}
    weights = {w: float(rng.uniform(0.5, 3.0)) for w in wids}
    topo = hierarchy.FogTopology.round_robin(wids, 2)
    hier = hierarchy.fog_aggregate_responses(responses, weights, topo)
    wn = np.array([weights[w] for w in wids])
    flat = aggregation.weighted_average([responses[w] for w in wids],
                                        wn / wn.sum())
    np.testing.assert_allclose(np.asarray(hier["p"]), np.asarray(flat["p"]),
                               **TOL)


def test_fog_topology_helpers():
    topo = hierarchy.FogTopology.round_robin(range(10), 3)
    assert topo.n_cells == 3
    cells = topo.cells()
    assert sorted(sum(cells.values(), [])) == list(range(10))
    sub = topo.restrict([0, 1, 2])
    assert set(sub.cell_of) == {0, 1, 2}
    rand = hierarchy.FogTopology.random(range(10), 3, seed=1)
    assert set(rand.cell_of) == set(range(10))
    assert 1 <= rand.n_cells <= 3


# -- end-to-end through the simulator --------------------------------------

def test_sim_with_fog_topology_matches_flat(synmnist, synmnist_test):
    from test_events import make_sim
    flat = make_sim(synmnist, synmnist_test, n_workers=4).run_sync(rounds=3)
    sim = make_sim(synmnist, synmnist_test, n_workers=4)
    sim.server.topology = hierarchy.FogTopology.round_robin(
        sim.workers.keys(), 2)
    fog = sim.run_sync(rounds=3)
    assert [r.time for r in flat.records] == [r.time for r in fog.records]
    np.testing.assert_allclose([r.acc for r in flat.records],
                               [r.acc for r in fog.records], atol=1e-3)
    for a, b in zip(jax.tree.leaves(flat.final_params),
                    jax.tree.leaves(fog.final_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
