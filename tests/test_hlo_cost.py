"""Golden tests for the trip-count-aware HLO cost model (dist/hlo_cost)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import hlo_cost


def _text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_match_unroll():
    def f_scan(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    def f_unroll(x, w):
        for i in range(8):
            x = jnp.tanh(x @ w[i])
        return x

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    cs = hlo_cost.analyze(_text(f_scan, x, w))
    cu = hlo_cost.analyze(_text(f_unroll, x, w))
    assert cs["diagnostics"] == []
    assert abs(cs["flops"] - cu["flops"]) / cu["flops"] < 0.02


def test_nested_scan_multiplies():
    def f(x, w):
        def outer(c, _):
            def inner(c2, wi):
                return jnp.tanh(c2 @ wi), None
            c, _ = jax.lax.scan(inner, c, w)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    c = hlo_cost.analyze(_text(f, x, w))
    expect = 2 * 128**3 * 8 * 3
    assert abs(c["flops"] - expect) / expect < 0.02


def test_dot_flops_exact():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 32), jnp.float32)
    c = hlo_cost.analyze(_text(f, a, b))
    expect = 2 * 64 * 256 * 32
    assert abs(c["flops"] - expect) / expect < 0.05


def test_xla_cost_analysis_undercounts_scans():
    """Documents WHY hlo_cost exists: XLA counts scan bodies once."""
    def f_scan(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((16, 128, 128), jnp.float32)
    compiled = jax.jit(f_scan).lower(x, w).compile()
    # cost_analysis() returns dict or [dict] depending on the jax version;
    # cost_analysis_terms normalises that (and is what dryrun records).
    from repro.dist.hlo_analysis import cost_analysis_terms
    xla_flops, _ = cost_analysis_terms(compiled)
    assert xla_flops > 0  # extraction worked; keeps the 10x check meaningful
    ours = hlo_cost.analyze(compiled.as_text())["flops"]
    assert ours > 10 * xla_flops  # 16 trips vs 1


def test_dus_counts_window_not_operand():
    """Scan ys writes (DUS on the stacked array) must charge the update
    window, not the full aliased operand (the basis of the memory-term
    fix; EXPERIMENTS.md SSPerf cell 2 it3)."""
    def f(big, small):
        return jax.lax.dynamic_update_slice(big, small, (0, 0))

    big = jax.ShapeDtypeStruct((4096, 512), jnp.float32)   # 8 MB
    small = jax.ShapeDtypeStruct((1, 512), jnp.float32)    # 2 KB
    mc = hlo_cost.ModuleCost(_text(f, big, small))
    dus = [(comp, op) for comp in mc.comps.values() for op in comp.ops
           if op.opcode == "dynamic-update-slice"]
    assert dus
    for comp, op in dus:
        assert mc.op_cost(comp, op).hbm_bytes < 1e5  # window, not 16 MB


def test_grad_of_scan_counts_fwd_and_bwd():
    def loss(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return jnp.sum(y * y)

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((12, 64, 64), jnp.float32)
    fwd = hlo_cost.analyze(_text(lambda a, b: loss(a, b), x, w))["flops"]
    both = hlo_cost.analyze(_text(jax.grad(loss, argnums=1), x, w))["flops"]
    assert both > 2.2 * fwd  # bwd ~2x fwd matmuls (+ tanh recompute)


def test_parser_handles_tuple_types_and_roots():
    text = """
HloModule m

%f (p0: f32[8,8]) -> (f32[8,8], s32[]) {
  %p0 = f32[8,8]{1,0} parameter(0)
  %c = s32[] constant(3)
  ROOT %t = (f32[8,8]{1,0}, s32[]) tuple(%p0, %c)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  ROOT %dot = f32[8,8]{1,0} dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    got = hlo_cost.analyze(text)
    assert got["flops"] == 2 * 8 * 8 * 8
    mc = hlo_cost.ModuleCost(text)
    root = [op for op in mc.comps["f"].ops if op.is_root][0]
    assert root.opcode == "tuple"
    assert [op.const_val for op in mc.comps["f"].ops
            if op.opcode == "constant"] == [3]


def test_collective_bytes_parse():
    from repro.dist.hlo_analysis import collective_bytes
    fake = """
  %ar = f32[1024,16]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = bf16[2048]{0} all-gather(%y), dimensions={0}
  %done = f32[8]{0} all-reduce-done(%s)
"""
    got = collective_bytes(fake)
    assert got["by_op"]["all-reduce"] == 1024 * 16 * 4
    assert got["by_op"]["all-gather"] == 2048 * 2
    assert got["count"] == 2  # -done not double-counted
