"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret=True on CPU, per the assignment)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

rng = np.random.default_rng(42)


# ---------------- fed_agg ----------------

@pytest.mark.parametrize("K", [1, 2, 5, 8])
@pytest.mark.parametrize("n", [128, 2048, 5000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fed_agg_sweep(K, n, dtype):
    from repro.kernels.fed_agg.ops import fed_agg
    from repro.kernels.fed_agg.ref import fed_agg_2d_ref
    x = jnp.asarray(rng.normal(size=(K, n)), dtype)
    w = jnp.asarray(rng.dirichlet([1.0] * K), jnp.float32)
    got = fed_agg(x, w)
    want = fed_agg_2d_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_fed_agg_tree_matches_weighted_average():
    from repro.core.aggregation import weighted_average
    from repro.kernels.fed_agg.ops import fed_agg_tree
    trees = [{"a": jnp.asarray(rng.normal(size=(33, 7)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(130,)), jnp.bfloat16)}
             for _ in range(3)]
    w = [0.2, 0.5, 0.3]
    got = fed_agg_tree(trees, w)
    want = weighted_average(trees, w)
    for g, x in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(x, np.float32),
                                   rtol=2e-2, atol=2e-2)


# ---------------- quant8 ----------------

@pytest.mark.parametrize("n", [64, 256, 1000, 4096])
@pytest.mark.parametrize("scale", [0.01, 1.0, 100.0])
def test_quant8_sweep(n, scale):
    from repro.core.compression import dequantize_blockwise, quantize_blockwise
    from repro.kernels.quant8.ops import dequantize, quantize
    x = jnp.asarray(rng.normal(size=(n,)) * scale, jnp.float32)
    q, s = quantize(x)
    qr, sr = quantize_blockwise(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    got = dequantize(q, s, (n,))
    want = dequantize_blockwise(qr, sr, (n,))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("impl", ["auto", "ref"])   # auto = interpret Pallas
@pytest.mark.parametrize("n", [100, 257, 1000])     # non-multiples of BLOCK
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant8_nonmultiple_and_bf16(impl, n, dtype):
    """Kernel<->reference parity on sizes that force the pad path and on
    bf16 inputs, in both interpret and ref modes."""
    from repro.core.compression import dequantize_blockwise, quantize_blockwise
    from repro.kernels.quant8.ops import dequantize, quantize
    x = jnp.asarray(rng.normal(size=(n,)) * 3.0, dtype)
    q, s = quantize(x, impl=impl)
    qr, sr = quantize_blockwise(x)
    # bf16 values sitting exactly on a rounding boundary may round one ulp
    # apart between the kernel and the reference; never more than that
    dq = np.abs(np.asarray(q, np.int32) - np.asarray(qr, np.int32))
    assert dq.max() <= (0 if dtype == jnp.float32 else 1)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    got = dequantize(q, s, (n,), impl=impl)
    want = dequantize_blockwise(qr, sr, (n,))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=float(np.max(np.asarray(sr))))


@pytest.mark.parametrize("impl", ["auto", "ref"])
def test_quant8_zero_delta_scale_clamp(impl):
    """All-zero input exercises the scale clamp: q == 0, scale == 0, and
    the roundtrip returns exact zeros (no NaN from the 0/0 guard)."""
    from repro.kernels.quant8.ops import dequantize, quantize
    x = jnp.zeros((777,), jnp.float32)
    q, s = quantize(x, impl=impl)
    assert not np.asarray(q).any() and not np.asarray(s).any()
    np.testing.assert_array_equal(np.asarray(dequantize(q, s, (777,),
                                                        impl=impl)), 0.0)


@pytest.mark.parametrize("impl", ["auto", "ref"])
@pytest.mark.parametrize("shape", [(5, 7), (3, 300), (1000,), (2, 3, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant8_rowwise_matches_reference(impl, shape, dtype):
    """The sharding-preserving rowwise layout (per last-dim channel):
    same shape out, exact parity with core.compression's reference, for
    lane-padded channel counts and bf16 inputs alike."""
    from repro.core import compression as comp
    from repro.kernels.quant8.ops import dequantize_rowwise, quantize_rowwise
    x = jnp.asarray(rng.normal(size=shape) * 2.0, dtype)
    q, s = quantize_rowwise(x, impl=impl)
    qr, sr = comp.quantize_rowwise(x)
    assert q.shape == x.shape and s.shape == x.shape[:-1] + (1,)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    got = dequantize_rowwise(q, s, impl=impl)
    want = comp.dequantize_rowwise(qr, sr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


# ---------------- flash attention ----------------

@pytest.mark.parametrize("T,H,Hkv,D,window,bq,bk", [
    (256, 4, 4, 64, 0, 128, 128),     # MHA causal
    (256, 4, 2, 64, 0, 128, 64),      # GQA, uneven blocks
    (512, 8, 1, 128, 0, 256, 256),    # MQA, D=128
    (512, 4, 2, 64, 128, 128, 128),   # sliding window
    (1024, 2, 2, 64, 300, 256, 256),  # window not block-aligned
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(T, H, Hkv, D, window, bq, bk, dtype):
    from repro.kernels.flash_attention.ops import flash_attention
    B = 2
    q = jnp.asarray(rng.normal(size=(B, T, H, D)) * 0.3, dtype)
    k = jnp.asarray(rng.normal(size=(B, T, Hkv, D)) * 0.3, dtype)
    v = jnp.asarray(rng.normal(size=(B, T, Hkv, D)), dtype)
    got = flash_attention(q, k, v, causal=True, window=window, bq=bq, bk=bk)
    want = flash_attention(q, k, v, causal=True, window=window, impl="ref")
    tol = 3e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_matches_model_xla_path():
    """Kernel vs the model's pure-XLA blockwise attention."""
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.models.layers import flash_attention_xla
    B, T, H, D = 2, 512, 4, 64
    q = jnp.asarray(rng.normal(size=(B, T, H, D)) * 0.3, jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, D)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, H, D)), jnp.float32)
    a = flash_attention(q, k, v, causal=True, bq=128, bk=128)
    b = flash_attention_xla(q, k, v, causal=True, q_block=128, kv_block=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-4,
                               atol=3e-4)


# ---------------- linrec ----------------

@pytest.mark.parametrize("B,T,D,bt,bd", [
    (1, 128, 128, 64, 128),
    (2, 512, 640, 256, 128),
    (3, 256, 512, 64, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_linrec_sweep(B, T, D, bt, bd, dtype):
    from repro.kernels.linrec.ops import linrec
    a = jnp.asarray(rng.uniform(0.7, 0.999, size=(B, T, D)), dtype)
    b = jnp.asarray(rng.normal(size=(B, T, D)) * 0.1, dtype)
    got = linrec(a, b, bt=bt, bd=bd)
    want = linrec(a, b, impl="ref")
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_linrec_matches_model_chunked_scan():
    from repro.kernels.linrec.ops import linrec
    from repro.models.ssm import _chunked_linear_scan
    B, T, D = 2, 256, 128
    a = jnp.asarray(rng.uniform(0.8, 0.999, size=(B, T, D)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, T, D)), jnp.float32)
    got = linrec(a, b, bt=64, bd=128)
    want, _ = _chunked_linear_scan(a, b, jnp.zeros((B, D)), chunk=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
