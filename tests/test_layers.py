"""Layer-level unit tests: attention paths, RoPE, MoE dispatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L
from repro.models.config import ModelConfig

rng = np.random.default_rng(7)


def test_blockwise_flash_matches_full():
    B, T, H, D = 2, 512, 4, 32
    q = jnp.asarray(rng.normal(size=(B, T, H, D)) * 0.3, jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, D)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, H, D)), jnp.float32)
    full = L.attention_full(q, k, v, causal=True)
    flash = L.flash_attention_xla(q, k, v, causal=True, q_block=128,
                                  kv_block=128)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(full),
                               rtol=3e-4, atol=3e-4)


def test_sliding_window_matches_full():
    B, T, H, D, W = 1, 512, 2, 32, 100
    q = jnp.asarray(rng.normal(size=(B, T, H, D)) * 0.3, jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, D)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, H, D)), jnp.float32)
    full = L.attention_full(q, k, v, causal=True, window=W)
    flash = L.flash_attention_xla(q, k, v, causal=True, window=W,
                                  q_block=128, kv_block=128)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(full),
                               rtol=3e-4, atol=3e-4)


def test_gqa_grouping_equivalent_to_repeat():
    B, T, H, Hkv, D = 1, 64, 8, 2, 16
    q = jnp.asarray(rng.normal(size=(B, T, H, D)) * 0.4, jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, Hkv, D)) * 0.4, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, Hkv, D)), jnp.float32)
    out = L.attention_full(q, k, v, causal=True)
    k_rep = jnp.repeat(k, H // Hkv, axis=2)
    v_rep = jnp.repeat(v, H // Hkv, axis=2)
    want = L.attention_full(q, k_rep, v_rep, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_decode_attention_matches_last_row_of_full():
    B, S, H, D = 2, 64, 4, 16
    q1 = jnp.asarray(rng.normal(size=(B, 1, H, D)) * 0.4, jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, D)) * 0.4, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    got = L.decode_attention(q1, k, v, jnp.full((B,), S, jnp.int32))
    # reference: full attention where the single query sits at position S-1
    want = L.attention_full(q1, k, v, causal=True, q_offset=S - 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_triangular_flash_matches_full():
    """The balanced-pair causal schedule must be numerically exact."""
    B, T, H, Hkv, D = 1, 512, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(B, T, H, D)) * 0.3, jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, Hkv, D)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, Hkv, D)), jnp.float32)
    import functools
    tri = L.flash_attention_xla_triangular
    got = tri.__wrapped__(q, k, v, block=64) if hasattr(tri, "__wrapped__") \
        else tri(q, k, v, block=64)
    want = L.attention_full(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


def test_triangular_flash_with_offset():
    B, T, H, D = 1, 256, 2, 16
    q = jnp.asarray(rng.normal(size=(B, T, H, D)) * 0.3, jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, D)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, H, D)), jnp.float32)
    got = L.flash_attention_xla_triangular(q, k, v, q_offset=0, block=64)
    want = L.flash_attention_xla(q, k, v, causal=True, q_block=64,
                                 kv_block=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


# ---------------- RoPE ----------------

def test_rope_preserves_norm():
    x = jnp.asarray(rng.normal(size=(2, 8, 4, 32)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    y = L.rope_apply(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)


def test_rope_relative_property():
    """<rope(q,m), rope(k,n)> depends only on m-n."""
    D = 32
    q = jnp.asarray(rng.normal(size=(1, 1, 1, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, D)), jnp.float32)

    def dot_at(m, n):
        qm = L.rope_apply(q, jnp.full((1, 1), m))
        kn = L.rope_apply(k, jnp.full((1, 1), n))
        return float(jnp.sum(qm * kn))

    assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4
    assert abs(dot_at(7, 7) - dot_at(0, 0)) < 1e-4


def test_partial_rope_passthrough():
    """fraction=0.5 leaves the last half of head_dim untouched (ChatGLM)."""
    x = jnp.asarray(rng.normal(size=(1, 4, 2, 16)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(4)[None], (1, 4))
    y = L.rope_apply(x, pos, fraction=0.5)
    np.testing.assert_array_equal(np.asarray(y[..., 8:]),
                                  np.asarray(x[..., 8:]))
    assert not np.array_equal(np.asarray(y[..., 1:8]),
                              np.asarray(x[..., 1:8]))


# ---------------- MoE ----------------

def _moe_cfg(E=4, k=2, cf=8.0):
    return ModelConfig(name="m", family="moe", num_layers=1, d_model=16,
                       num_heads=2, num_kv_heads=2, head_dim=8, d_ff=32,
                       vocab_size=64, num_experts=E, experts_per_token=k,
                       moe_d_ff=32, capacity_factor=cf, remat=False)


def _dense_moe_ref(p, cfg, x):
    """Dense reference: route every token through its top-k experts."""
    B, T, d = x.shape
    xt = np.asarray(x, np.float32).reshape(-1, d)
    logits = xt @ np.asarray(p["w_router"], np.float32)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    k = cfg.experts_per_token
    f = cfg.moe_d_ff
    out = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        idx = np.argsort(-probs[t])[:k]
        w = probs[t, idx] / probs[t, idx].sum()
        for e, wi in zip(idx, w):
            g = xt[t] @ np.asarray(p["w_gate"][e], np.float32)
            u = xt[t] @ np.asarray(p["w_up"][e], np.float32)
            h = (g / (1 + np.exp(-g))) * u
            out[t] += wi * (h @ np.asarray(p["w_down"][e], np.float32))
    return out.reshape(B, T, d)


def test_moe_matches_dense_reference_when_no_drops():
    cfg = _moe_cfg(cf=8.0)  # capacity ample: nothing dropped
    from repro.models.param import init_params
    p = init_params(jax.random.key(0), L.moe_defs(cfg))
    p = jax.tree.map(lambda a: a.astype(jnp.float32), p)
    x = jnp.asarray(rng.normal(size=(2, 8, 16)), jnp.float32)
    got, aux = L.moe_apply(p, cfg, x)
    want = _dense_moe_ref(p, cfg, x)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-3)
    assert float(aux) > 0.0


def test_moe_capacity_drops_dont_crash_and_bounded():
    cfg = _moe_cfg(cf=0.25)  # tiny capacity: most tokens dropped
    from repro.models.param import init_params
    p = init_params(jax.random.key(0), L.moe_defs(cfg))
    x = jnp.asarray(rng.normal(size=(2, 32, 16)), jnp.bfloat16)
    got, _ = L.moe_apply(p, cfg, x)
    assert got.shape == x.shape
    assert not bool(jnp.isnan(got.astype(jnp.float32)).any())


def test_moe_load_balance_loss_uniform_is_one():
    """Perfectly uniform routing gives aux loss ~= 1 (Switch normalisation)."""
    E, k, n = 8, 2, 4096
    probs = jnp.full((n, E), 1.0 / E)
    gidx = jnp.asarray(rng.integers(0, E, size=(n, k)))
    onehot = jax.nn.one_hot(gidx, E, dtype=jnp.int32)
    loss = L._load_balance_loss(probs, onehot, E, k)
    assert abs(float(loss) - 1.0) < 0.05
