"""Load-generator determinism + trace-driver semantics (launch/loadgen.py).

Same SimRecord discipline as the scenario engine (tests/test_scenarios.py):
the full record stream -- arrival times, prompts, output budgets, and on a
virtual clock even the per-request outputs and timestamps -- must be a pure
function of the seed."""
import numpy as np
import pytest

import jax

from repro.configs import get_smoke_config
from repro.launch import loadgen
from repro.launch.serve_loop import PagedServeLoop, ServeLoop
from repro.models import build_model


def _cfg(**kw):
    base = dict(qps=20.0, duration_s=1.0, seed=11, vocab_size=499,
                prompt_mean=12, prompt_max=40, out_mean=5, out_max=10,
                shared_prefix_frac=0.3, shared_prefix_len=8)
    base.update(kw)
    return loadgen.LoadConfig(**base)


def test_generate_is_deterministic():
    a = loadgen.generate(_cfg())
    b = loadgen.generate(_cfg())
    assert len(a) == len(b) > 0
    for x, y in zip(a, b):
        assert x.rid == y.rid
        assert x.t == y.t
        assert x.max_new == y.max_new
        np.testing.assert_array_equal(x.prompt, y.prompt)


def test_generate_seed_changes_trace():
    a = loadgen.generate(_cfg())
    b = loadgen.generate(_cfg(seed=12))
    assert [x.t for x in a] != [y.t for y in b]


def test_generate_respects_bounds():
    arrivals = loadgen.generate(_cfg(duration_s=2.0))
    assert all(0 < a.t < 2.0 for a in arrivals)
    assert all(4 <= len(a.prompt) <= 40 for a in arrivals)
    assert all(2 <= a.max_new <= 10 for a in arrivals)
    # open loop: arrival times are sorted and rate is in the right ballpark
    ts = [a.t for a in arrivals]
    assert ts == sorted(ts)
    assert 10 <= len(arrivals) <= 80        # 20 qps x 2 s, poisson spread


def test_shared_prefixes_present():
    arrivals = loadgen.generate(_cfg(shared_prefix_frac=1.0,
                                     n_prefixes=1))
    first = arrivals[0].prompt[:8]
    for a in arrivals:
        np.testing.assert_array_equal(a.prompt[:8], first)


def test_virtual_clock_run_is_deterministic():
    """Two full virtual-clock runs (fresh loops, same seed) produce
    identical records: timestamps, prompts, and generated tokens."""
    cfg = get_smoke_config("granite-20b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    trace = loadgen.generate(_cfg(qps=30.0, duration_s=0.5))

    def run():
        loop = PagedServeLoop(model, params, max_batch=2, num_blocks=32,
                              block_size=8, chunk=16)
        return loadgen.run_trace(loop, trace, tick_s=0.01)

    r1, r2 = run(), run()
    assert r1 == r2
    assert all(rec.t_done >= rec.t_first >= rec.t_arrive >= 0 for rec in r1)


def test_summarize_percentiles():
    recs = [loadgen.ServedRecord(rid=i, t_arrive=0.0, t_first=0.1,
                                 t_done=0.1 * (i + 1), n_prompt=4,
                                 out=(1, 2, 3))
            for i in range(10)]
    s = loadgen.summarize(recs, wall_s=2.0)
    assert s["n_requests"] == 10
    assert s["tokens_out"] == 30
    assert s["tokens_per_s"] == 15.0
    assert s["p50_ms"] == pytest.approx(550.0, abs=20)
    assert s["p99_ms"] <= 1000.0
    assert s["ttft_p50_ms"] == pytest.approx(100.0, abs=1)


@pytest.mark.scale
def test_load_smoke_invariants():
    """Small end-to-end load test against the benchmark's invariants
    (paged==contiguous parity, prefix sharing active); the full QPS run
    lives in benchmarks/serve_load.py (serve CI step)."""
    cfg = get_smoke_config("granite-20b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    trace = loadgen.generate(loadgen.LoadConfig(
        qps=20.0, duration_s=1.0, seed=5, vocab_size=cfg.vocab_size,
        prompt_mean=16, prompt_max=48, out_mean=6, out_max=12,
        shared_prefix_frac=0.5, shared_prefix_len=16))
    ploop = PagedServeLoop(model, params, max_batch=4, num_blocks=48,
                           block_size=8, chunk=32)
    cloop = ServeLoop(model, params, max_batch=4, max_len=384)
    got = loadgen.run_trace(ploop, trace, tick_s=0.01)
    want = loadgen.run_trace(cloop, trace, tick_s=0.01)
    assert [r.out for r in got] == [r.out for r in want]
    assert ploop.alloc.stats["shared_blocks"] > 0
    ploop.alloc.check_invariants()