"""Per-architecture smoke tests: reduced same-family config, one forward +
one real train step on CPU, asserting shapes and no NaNs (assignment SSf)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config, list_archs
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.models.config import SHAPES, ShapeConfig
from repro.optim import adamw

DEV = ShapeConfig("dev", "train", 32, 2)

ARCHS = list_archs()


def batch_for(model, shape, seed=0):
    rng = np.random.default_rng(seed)
    cfg = model.cfg
    b = {}
    for k, d in model.input_defs(shape).items():
        if d.dtype == jnp.int32:
            hi = cfg.vocab_size if k in ("tokens", "labels") else shape.seq_len
            b[k] = jnp.asarray(rng.integers(0, max(hi, 2), d.shape), jnp.int32)
        else:
            b[k] = jnp.asarray(rng.normal(size=d.shape), d.dtype)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = batch_for(model, DEV)
    logits, aux = model.apply(params, batch, mode="train")
    assert logits.shape == (DEV.global_batch, DEV.seq_len, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_updates_params(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    opt = adamw(1e-2)
    step = jax.jit(make_train_step(model, opt))
    params = model.init(jax.random.key(0))
    opt_state = opt.init(params)
    batch = batch_for(model, DEV)
    new_params, opt_state, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # at least one leaf changed
    changed = any(
        not np.array_equal(np.asarray(a, np.float32),
                           np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert changed


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "mixtral-8x22b": (56, 6144, 48, 8, 32768),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 151936),
        "qwen1.5-4b": (40, 2560, 20, 20, 151936),
        "chatglm3-6b": (28, 4096, 32, 2, 65024),
        "granite-20b": (52, 6144, 48, 1, 49152),
        "minitron-8b": (32, 4096, 32, 8, 256000),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 32064),
        "recurrentgemma-9b": (38, 4096, 16, 1, 256000),
        "falcon-mamba-7b": (64, 4096, 0, 0, 65024),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 256206),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.vocab_size)
    assert got == expected, (arch, got, expected)


def test_moe_active_params_less_than_total():
    m = build_model(get_config("mixtral-8x22b"))
    assert m.n_active_params < m.n_params
    # 8 experts top-2: expert params scale ~2/8
    q = build_model(get_config("qwen3-moe-235b-a22b"))
    assert q.n_active_params < 0.2 * q.n_params


def test_param_counts_in_expected_range():
    """Sanity: derived parameter counts match the models' nominal sizes."""
    expect = {
        "mixtral-8x22b": (130e9, 150e9),
        "qwen3-moe-235b-a22b": (200e9, 260e9),
        "qwen1.5-4b": (3e9, 5e9),
        "chatglm3-6b": (5.5e9, 7.5e9),
        "granite-20b": (18e9, 23e9),
        "minitron-8b": (7e9, 10.5e9),
        "phi-3-vision-4.2b": (3.3e9, 4.6e9),
        "recurrentgemma-9b": (7.5e9, 11e9),
        "falcon-mamba-7b": (6e9, 8.5e9),
        "seamless-m4t-large-v2": (0.8e9, 1.8e9),
    }
    for arch, (lo, hi) in expect.items():
        n = build_model(get_config(arch)).n_params
        assert lo <= n <= hi, (arch, n)
