"""Optimizer substrate tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.param import pdef, abstract_params
from repro.optim import (adamw, apply_updates, clip_by_global_norm,
                         global_norm, opt_state_defs, sgd_momentum)
from repro.optim.schedules import cosine_warmup, linear_warmup


def test_adamw_converges_quadratic():
    opt = adamw(0.1)
    params = {"x": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_sgd_momentum_first_step():
    opt = sgd_momentum(0.5, momentum=0.9)
    params = {"x": jnp.array([1.0])}
    state = opt.init(params)
    g = {"x": jnp.array([2.0])}
    upd, state = opt.update(g, state, params)
    np.testing.assert_allclose(np.asarray(upd["x"]), [-1.0])  # -lr*g
    upd2, _ = opt.update(g, state, params)
    np.testing.assert_allclose(np.asarray(upd2["x"]), [-0.5 * (0.9 * 2 + 2)])


def test_params_keep_dtype_through_update():
    opt = adamw(0.01)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = opt.init(params)
    assert state["mu"]["w"].dtype == jnp.float32
    g = {"w": jnp.ones((4,), jnp.bfloat16)}
    upd, state = opt.update(g, state, params)
    new = apply_updates(params, upd)
    assert new["w"].dtype == jnp.bfloat16


def test_clip_by_global_norm():
    t = {"a": jnp.full((3,), 10.0)}
    clipped, norm = clip_by_global_norm(t, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    assert float(norm) > 1.0


def test_opt_state_defs_mirror_shapes():
    pdefs = {"w": pdef((8, 4), ("embed", "ffn")),
             "b": pdef((4,), (None,))}
    odefs = opt_state_defs(pdefs)
    assert odefs["mu"]["w"].shape == (8, 4)
    assert odefs["mu"]["w"].dtype == jnp.float32
    assert odefs["nu"]["b"].logical_axes == (None,)
    abstract_params(odefs)  # must be materialisable


def test_schedules():
    lw = linear_warmup(1.0, 10)
    assert float(lw(jnp.int32(5))) == 0.5
    cw = cosine_warmup(1.0, 10, 110, floor=0.1)
    assert float(cw(jnp.int32(10))) == 1.0
    assert abs(float(cw(jnp.int32(110))) - 0.1) < 1e-6
