"""Property tests for the block-table KV-cache allocator (core/paging.py).

Under arbitrary admit/extend/finish sequences:
  * no block is ever assigned to two owners (double-assignment);
  * refcounts hit zero exactly when the last sharer finishes;
  * free + cached + active block counts always sum to the pool size.
Driven by hypothesis when installed, else the deterministic fallback shim.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback (tests/_hypothesis_compat.py)
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.paging import AdmitResult, BlockAllocator, OutOfBlocks


def _random_workload(alloc: BlockAllocator, ops: list, prompt_pool: list):
    """Interpret a generated op list against the allocator, checking the
    invariants after EVERY operation."""
    rng = np.random.default_rng(0xC0FFEE)
    live: dict[int, int] = {}   # seq_id -> current length
    next_sid = 0
    for op in ops:
        if op == 0 or not live:          # admit
            prompt = prompt_pool[int(rng.integers(len(prompt_pool)))]
            try:
                alloc.admit(next_sid, prompt, reserve=1)
                live[next_sid] = len(prompt)
                next_sid += 1
            except OutOfBlocks:
                pass                     # pool full: a valid outcome
        elif op == 1:                    # extend (one decode step)
            sid = list(live)[int(rng.integers(len(live)))]
            try:
                alloc.ensure_capacity(sid, live[sid])
                live[sid] += 1
            except OutOfBlocks:
                pass
        else:                            # finish
            sid = list(live)[int(rng.integers(len(live)))]
            alloc.finish(sid)
            del live[sid]
        alloc.check_invariants()
    for sid in list(live):
        alloc.finish(sid)
    alloc.check_invariants()


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=4, max_value=24),
       st.integers(min_value=2, max_value=8),
       st.lists(st.integers(min_value=0, max_value=2), min_size=1,
                max_size=60),
       st.integers(min_value=0, max_value=10_000))
def test_allocator_invariants_random_ops(num_blocks, block_size, ops, seed):
    rng = np.random.default_rng(seed)
    # a pool of prompts with overlapping prefixes so sharing triggers
    base = rng.integers(0, 100, 4 * block_size).tolist()
    prompt_pool = [
        base[: block_size + 1],
        base[: 2 * block_size + 3],
        base[: 3 * block_size],
        rng.integers(0, 100, block_size + 2).tolist(),
        rng.integers(0, 100, 1).tolist(),
    ]
    _random_workload(BlockAllocator(num_blocks, block_size),
                     ops, prompt_pool)


def test_all_blocks_free_after_everything_finishes():
    alloc = BlockAllocator(16, 4)
    for sid, n in enumerate((3, 9, 17)):
        alloc.admit(sid, list(range(n)))
    for sid in range(3):
        alloc.finish(sid)
    alloc.check_invariants()
    # registered blocks stay cached (warm), the rest return to free; all
    # 16 are reclaimable and none active
    assert alloc.n_free() == 16
    assert all(r == 0 for r in alloc.ref)


def test_prefix_sharing_refcounts():
    alloc = BlockAllocator(16, 4)
    prompt = list(range(10))              # blocks: 2 full + 1 tail
    r1 = alloc.admit(1, prompt)
    assert isinstance(r1, AdmitResult) and r1.n_shared_blocks == 0
    r2 = alloc.admit(2, prompt)
    assert r2.n_shared_blocks == 2        # both full blocks re-used
    assert r2.table[:2] == r1.table[:2]
    assert r2.table[2] != r1.table[2]     # tail is private
    shared = r1.table[:2]
    assert all(alloc.ref[b] == 2 for b in shared)
    alloc.finish(1)
    alloc.check_invariants()
    assert all(alloc.ref[b] == 1 for b in shared), \
        "refcount must stay >0 while a sharer lives"
    alloc.finish(2)
    assert all(alloc.ref[b] == 0 for b in shared), \
        "refcount must reach 0 when the last sharer finishes"
    alloc.check_invariants()


def test_shared_block_never_freed_while_referenced():
    alloc = BlockAllocator(8, 4)
    prompt = list(range(9))
    alloc.admit(1, prompt)
    alloc.admit(2, prompt)
    alloc.finish(1)
    # burn through the free list; the evictable cache may be raided but
    # seq 2's referenced blocks must survive
    t2 = alloc.table(2)
    sids = []
    for sid in range(3, 20):
        try:
            alloc.admit(sid, [100 + sid])
            sids.append(sid)
        except OutOfBlocks:
            break
        alloc.check_invariants()
    assert alloc.table(2) == t2
    assert all(alloc.ref[b] >= 1 for b in t2)
    for sid in [2] + sids:
        alloc.finish(sid)
    alloc.check_invariants()


def test_eviction_reclaims_cached_blocks():
    alloc = BlockAllocator(6, 2)
    alloc.admit(1, list(range(8)))        # 4 full + 1 reserve = 5 blocks
    alloc.finish(1)                       # 4 registered, 1 free + 1 never used
    assert len(alloc.cached) == 4
    # a new prompt with a different prefix must evict LRU cached blocks
    alloc.admit(2, list(range(50, 58)))
    alloc.check_invariants()
    assert alloc.stats["evictions"] >= 3
    alloc.finish(2)


def test_out_of_blocks_leaves_state_unchanged():
    alloc = BlockAllocator(4, 2)
    alloc.admit(1, list(range(5)))        # 3 blocks + reserve = 4: pool full
    before = (list(alloc.free), list(alloc.ref), dict(alloc.cached))
    with pytest.raises(OutOfBlocks):
        alloc.admit(2, list(range(20, 29)))
    assert (list(alloc.free), list(alloc.ref), dict(alloc.cached)) == before
    alloc.check_invariants()
    alloc.finish(1)


def test_admit_rejects_duplicate_seq_and_empty():
    alloc = BlockAllocator(4, 2)
    alloc.admit(1, [1, 2, 3])
    with pytest.raises(AssertionError):
        alloc.admit(1, [1, 2, 3])
    with pytest.raises(AssertionError):
        alloc.admit(2, [])
