"""Layout-policy decision table + hbm_bytes calibration regressions.

Covers the three pieces the memory-aware serve layout rests on:
  * dist.policy.decide over tiny fake memory_analysis dicts (margin edge
    cases, tie-breaking, the huge-MoE nothing-fits fallback);
  * the HYBRID_SERVE_RULES factory (vocab tables shard over data, body
    weights stay stationary);
  * the calibrated fusion-boundary model: window reads for slice-only
    fusion params, and the end-to-end CNN-on-256-device cell landing
    within 2x of XLA's bytes-accessed.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.dist import hlo_cost, policy
from repro.dist.sharding import (HYBRID_SERVE_RULES, SERVE_RULES,
                                 abstract_mesh, logical_to_mesh_spec,
                                 serve_layout_rules)


def _eval(layout, args=0, temp=0, out=0, alias=0, bound_s=1.0):
    return policy.eval_from_compiled(
        layout,
        {"argument_size_in_bytes": args, "temp_size_in_bytes": temp,
         "output_size_in_bytes": out, "alias_size_in_bytes": alias},
        {"bound_s": bound_s})


GB = int(1e9)


# ---------------------------------------------------------------------------
# Decision table
# ---------------------------------------------------------------------------

def test_fastest_feasible_wins():
    d = policy.decide([
        _eval("stationary", args=10 * GB, bound_s=0.01),
        _eval("hybrid", args=6 * GB, bound_s=0.02),
        _eval("fsdp", args=2 * GB, bound_s=0.50),
    ], budget_bytes=16e9, margin=0.9)
    assert d.layout == "stationary" and d.fits
    assert d.headroom_bytes() == pytest.approx(16e9 * 0.9 - 10 * GB)
    assert "headroom" in d.reason


def test_over_budget_candidate_excluded():
    d = policy.decide([
        _eval("stationary", args=15 * GB, bound_s=0.01),   # > 14.4 GB cap
        _eval("fsdp", args=2 * GB, bound_s=0.50),
    ], budget_bytes=16e9, margin=0.9)
    assert d.layout == "fsdp" and d.fits


def test_margin_edge_exactly_at_cap_is_feasible():
    cap = 16e9 * 0.9
    d = policy.decide([_eval("stationary", args=int(cap), bound_s=0.01),
                       _eval("fsdp", args=GB, bound_s=1.0)],
                      budget_bytes=16e9, margin=0.9)
    assert d.layout == "stationary" and d.fits


def test_margin_edge_one_byte_over_cap_is_not():
    cap = 16e9 * 0.9
    d = policy.decide([_eval("stationary", args=int(cap) + 1, bound_s=0.01),
                       _eval("fsdp", args=GB, bound_s=1.0)],
                      budget_bytes=16e9, margin=0.9)
    assert d.layout == "fsdp"


def test_huge_moe_nothing_fits_falls_back_to_min_peak():
    d = policy.decide([
        _eval("stationary", args=55 * GB, bound_s=0.07),
        _eval("hybrid", args=27 * GB, bound_s=0.6),
        _eval("fsdp", args=20 * GB, bound_s=0.6),
    ], budget_bytes=16e9, margin=0.9)
    assert d.layout == "fsdp"
    assert not d.fits
    assert d.headroom_bytes() < 0
    assert "no layout fits" in d.reason


def test_step_time_tie_prefers_more_stationary():
    # evals arrive most-stationary-first; min() is stable on ties
    d = policy.decide([_eval("stationary", args=GB, bound_s=0.1),
                       _eval("hybrid", args=GB, bound_s=0.1),
                       _eval("fsdp", args=GB, bound_s=0.1)])
    assert d.layout == "stationary"


def test_peak_counts_nonaliased_output_only():
    # donated caches alias their argument: only out - alias adds to peak
    e = _eval("x", args=4 * GB, temp=GB, out=3 * GB, alias=3 * GB)
    assert e.hbm_bytes == pytest.approx(5 * GB)
    e2 = _eval("x", args=4 * GB, temp=GB, out=3 * GB, alias=0)
    assert e2.hbm_bytes == pytest.approx(8 * GB)


def test_decide_requires_candidates():
    with pytest.raises(ValueError):
        policy.decide([])


# ---------------------------------------------------------------------------
# Rule-set factory
# ---------------------------------------------------------------------------

def test_serve_layout_rules_factory():
    assert serve_layout_rules("stationary") is SERVE_RULES
    assert serve_layout_rules("hybrid") is HYBRID_SERVE_RULES
    with pytest.raises(KeyError):
        serve_layout_rules("nope")


def test_hybrid_shards_vocab_tables_over_model_and_data():
    mesh = abstract_mesh((4, 8), ("data", "model"))
    # the embedding table (vocab, embed): vocab takes the (model, data)
    # stack, the body d_model dim stays replicated
    spec = logical_to_mesh_spec(("vocab", "embed"), (64, 48), mesh,
                                HYBRID_SERVE_RULES)
    assert spec[0] == ("model", "data")
    assert spec[1] is None
    # body weights are untouched vs stationary serving
    for axes, shape in ((("embed", "ffn"), (48, 64)),
                        (("embed", "heads", None), (48, 8, 16))):
        assert logical_to_mesh_spec(axes, shape, mesh, HYBRID_SERVE_RULES) \
            == logical_to_mesh_spec(axes, shape, mesh, SERVE_RULES)


def test_hybrid_vocab_falls_back_to_model_when_indivisible():
    mesh = abstract_mesh((4, 8), ("data", "model"))
    # 24 divides by model=8 but not by model*data=32: longest divisible
    # prefix of the stack wins, same layout as stationary
    spec = logical_to_mesh_spec(("vocab", "embed"), (24, 48), mesh,
                                HYBRID_SERVE_RULES)
    assert spec[0] == "model"


# ---------------------------------------------------------------------------
# Calibrated fusion-boundary model
# ---------------------------------------------------------------------------

_WINDOW_HLO = """
HloModule m

%fused_dus (p0: f32[4096,512], p1: f32[4096], p2: s32[]) -> f32[4096,512] {
  %p0 = f32[4096,512]{1,0} parameter(0)
  %p1 = f32[4096]{0} parameter(1)
  %p2 = s32[] parameter(2)
  %c0 = s32[] constant(0)
  %ds = f32[1,512]{1,0} dynamic-slice(f32[4096,512]{1,0} %p0, s32[] %p2, s32[] %c0), dynamic_slice_sizes={1,512}
  %ds2 = f32[1]{0} dynamic-slice(f32[4096]{0} %p1, s32[] %p2), dynamic_slice_sizes={1}
  %b = f32[1,512]{1,0} broadcast(f32[1]{0} %ds2), dimensions={0}
  %a = f32[1,512]{1,0} add(f32[1,512]{1,0} %ds, f32[1,512]{1,0} %b)
  ROOT %dus = f32[4096,512]{1,0} dynamic-update-slice(f32[4096,512]{1,0} %p0, f32[1,512]{1,0} %a, s32[] %p2, s32[] %c0)
}

%body (param: (s32[], f32[4096,512], f32[4096])) -> (s32[], f32[4096,512], f32[4096]) {
  %param = (s32[], f32[4096,512]{1,0}, f32[4096]{0}) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[4096,512]{1,0}, f32[4096]{0}) %param), index=0
  %big = f32[4096,512]{1,0} get-tuple-element((s32[], f32[4096,512]{1,0}, f32[4096]{0}) %param), index=1
  %vec = f32[4096]{0} get-tuple-element((s32[], f32[4096,512]{1,0}, f32[4096]{0}) %param), index=2
  %one = s32[] constant(1)
  %next = s32[] add(s32[] %i, s32[] %one)
  %upd = f32[4096,512]{1,0} fusion(f32[4096,512]{1,0} %big, f32[4096]{0} %vec, s32[] %i), kind=kLoop, calls=%fused_dus
  ROOT %out = (s32[], f32[4096,512]{1,0}, f32[4096]{0}) tuple(s32[] %next, f32[4096,512]{1,0} %upd, f32[4096]{0} %vec)
}

%cond (param.1: (s32[], f32[4096,512], f32[4096])) -> pred[] {
  %param.1 = (s32[], f32[4096,512]{1,0}, f32[4096]{0}) parameter(0)
  %i.1 = s32[] get-tuple-element((s32[], f32[4096,512]{1,0}, f32[4096]{0}) %param.1), index=0
  %n = s32[] constant(4096)
  ROOT %lt = pred[] compare(s32[] %i.1, s32[] %n), direction=LT
}

ENTRY %main (arg: (s32[], f32[4096,512], f32[4096])) -> (s32[], f32[4096,512], f32[4096]) {
  %arg = (s32[], f32[4096,512]{1,0}, f32[4096]{0}) parameter(0)
  ROOT %w = (s32[], f32[4096,512]{1,0}, f32[4096]{0}) while((s32[], f32[4096,512]{1,0}, f32[4096]{0}) %arg), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"4096"}}
}
"""


def test_fusion_slice_only_params_charge_windows():
    """A 4096-trip loop whose fusion slices one row per trip must charge
    ~one full pass over the arrays, not 4096 full passes."""
    c = hlo_cost.analyze(_WINDOW_HLO)
    full_pass = 4096 * 512 * 4          # the big array, once
    # per trip: row read (2 KB) + scalar + row write -> ~2 passes total
    assert c["hbm_bytes"] < 4 * full_pass
    assert c["hbm_bytes"] > 0.5 * full_pass
    # the un-calibrated model charged the full operand every trip:
    assert c["hbm_bytes"] < (4096 * full_pass) / 100


def test_fusion_non_slice_use_still_charges_full_operand():
    text = """
HloModule m

%f (p0: f32[1024,1024], p1: f32[1024,1024]) -> f32[1024,1024] {
  %p0 = f32[1024,1024]{1,0} parameter(0)
  %p1 = f32[1024,1024]{1,0} parameter(1)
  ROOT %a = f32[1024,1024]{1,0} add(f32[1024,1024]{1,0} %p0, f32[1024,1024]{1,0} %p1)
}

ENTRY %main (x: f32[1024,1024], y: f32[1024,1024]) -> f32[1024,1024] {
  %x = f32[1024,1024]{1,0} parameter(0)
  %y = f32[1024,1024]{1,0} parameter(1)
  ROOT %fu = f32[1024,1024]{1,0} fusion(f32[1024,1024]{1,0} %x, f32[1024,1024]{1,0} %y), kind=kLoop, calls=%f
}
"""
    c = hlo_cost.analyze(text)
    buf = 1024 * 1024 * 4
    assert c["hbm_bytes"] == pytest.approx(3 * buf)  # 2 reads + 1 write


# ---------------------------------------------------------------------------
# End-to-end calibration regression (compiles the CNN cell on a fake
# 256-device mesh in a subprocess: dryrun must set XLA_FLAGS pre-import)
# ---------------------------------------------------------------------------

def test_cnn_hbm_calibrated_vs_xla(tmp_path):
    """CNN train on the 256-device mesh: replicated-compute cells used to
    report ~3600x XLA's bytes-accessed through the select-and-scatter
    while loop; calibrated model must stay within 2x."""
    env = dict(os.environ, REPRO_DRYRUN_DIR="dryrun_test",
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH",
                                                              ""))
    root = Path(__file__).resolve().parents[1]
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "flight-cnn-mnist", "--shape", "train_4k", "--mesh", "single",
         "--force"],
        cwd=root, env=env, capture_output=True, text=True, timeout=600)
    art = root / "artifacts" / "dryrun_test" / \
        "flight-cnn-mnist__train_4k__single.json"
    try:
        assert r.returncode == 0, r.stderr[-2000:]
        rec = json.loads(art.read_text())
        e = rec["entries"]["train_step"]
        ours = e["hlo_cost"]["hbm_bytes"]
        xla = e["xla_cost_analysis_once"]["bytes_accessed"]
        assert xla > 0
        assert ours <= 2.0 * xla, f"hbm_bytes {ours:.3g} vs XLA {xla:.3g}"
        assert ours >= 0.1 * xla, f"hbm_bytes {ours:.3g} vs XLA {xla:.3g}"
    finally:
        if art.exists():
            art.unlink()
