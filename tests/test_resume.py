"""Crash-safe deterministic resume, both engines x both modes.

A run killed by a seeded server crash (faults.FaultPlan.server_crash_rounds)
and then resumed from its round-granular checkpoint must produce the SAME
SimRecord stream as an uninterrupted run: concat(interrupted, resumed) ==
reference, field-for-field.  Byzantine corruption, response drops, and
duplicate deliveries are active throughout so the restored state covers the
RNG, the jax key, the server policy state, quarantine counters, and (async)
the in-flight response heap.
"""
import dataclasses

import pytest

from repro.checkpoint import CheckpointManager
from repro.core.faults import FaultConfig, FaultPlan
from test_events import make_sim

FAULTS = FaultConfig(byzantine_frac=0.3, attacks=("sign_flip", "scale"),
                     scale_factor=8.0, drop_frac=0.1, duplicate_frac=0.1,
                     seed=11)


def sim_with(synmnist, synmnist_test, *, mode, faults, ckpt):
    sim = make_sim(synmnist, synmnist_test, n_workers=5, mode=mode,
                   batches=[2] * 5, seed=11)
    sim.faults = FaultPlan(faults) if faults is not None else None
    sim.ckpt = ckpt
    return sim


@pytest.mark.parametrize("mode,crash_at", [("sync", 2), ("async", 4)])
def test_events_resume_is_bit_identical(synmnist, synmnist_test, tmp_path,
                                        mode, crash_at):
    crashing = dataclasses.replace(FAULTS, server_crash_rounds=(crash_at,))
    run = (lambda s, **kw: s.run_sync(5, **kw)) if mode == "sync" else \
          (lambda s, **kw: s.run_async(8, **kw))

    ref = run(sim_with(synmnist, synmnist_test, mode=mode, faults=FAULTS,
                       ckpt=None))
    assert not ref.crashed

    mgr = CheckpointManager(str(tmp_path / mode))
    r1 = run(sim_with(synmnist, synmnist_test, mode=mode, faults=crashing,
                      ckpt=mgr))
    assert r1.crashed and len(r1.records) < len(ref.records)

    # a FRESH process: new sim object, same construction, resume from disk
    r2 = run(sim_with(synmnist, synmnist_test, mode=mode, faults=crashing,
                      ckpt=mgr), resume=True)
    assert not r2.crashed            # the pending crash already happened
    assert r1.records + r2.records == ref.records


@pytest.mark.parametrize("mode,crash_at", [("sync", 2), ("async", 5)])
def test_scenarios_resume_is_bit_identical(tmp_path, mode, crash_at):
    from repro.core.scenarios import ScenarioConfig, ScenarioSim
    cfg = ScenarioConfig(n_workers=40, cohort_size=6, fog_cells=2,
                         participation=0.4, samples_per_worker=32,
                         byzantine_frac=0.25, byzantine_scale=8.0,
                         robust_agg="trimmed_mean", trim_frac=0.3,
                         server_crash_round=crash_at, seed=5)
    clean = dataclasses.replace(cfg, server_crash_round=0)
    run = (lambda s, **kw: s.run_sync(4, **kw)) if mode == "sync" else \
          (lambda s, **kw: s.run_async(8, **kw))

    ref = run(ScenarioSim(clean, pool=256, eval_n=128))
    assert not ref.crashed

    mgr = CheckpointManager(str(tmp_path / mode))
    r1 = run(ScenarioSim(cfg, pool=256, eval_n=128, ckpt=mgr))
    assert r1.crashed and len(r1.records) < len(ref.records)

    r2 = run(ScenarioSim(cfg, pool=256, eval_n=128, ckpt=mgr), resume=True)
    assert not r2.crashed
    assert r1.records + r2.records == ref.records


def test_resume_without_checkpoint_starts_fresh(synmnist, synmnist_test,
                                                tmp_path):
    """resume=True with an empty checkpoint dir is a plain cold start."""
    mgr = CheckpointManager(str(tmp_path / "empty"))
    sim = sim_with(synmnist, synmnist_test, mode="sync", faults=None,
                   ckpt=mgr)
    ref = sim_with(synmnist, synmnist_test, mode="sync", faults=None,
                   ckpt=None).run_sync(2)
    res = sim.run_sync(2, resume=True)
    assert res.records == ref.records
