"""Property tests for the Byzantine-robust aggregators (core/aggregation.py).

  * permutation invariance: member order never changes the fold;
  * degenerate agreement: identical updates come back unchanged, and
    trim_frac=0 trimmed mean == the uniform mean;
  * breakdown bound: with f corrupt members at arbitrary magnitude and a
    matched trim/krum budget, the fold stays inside the honest members'
    coordinate-wise envelope (trimmed/median/krum) or norm ball (norm_clip).
Driven by hypothesis when installed, else the deterministic fallback shim.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback (tests/_hypothesis_compat.py)
    from _hypothesis_compat import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import aggregation as agg

members_st = st.integers(min_value=3, max_value=9)
seed_st = st.integers(min_value=0, max_value=2**31 - 1)


def make_members(P: int, seed: int):
    rng = np.random.default_rng(seed)
    return [{"w": jnp.asarray(rng.normal(size=(3, 2)), jnp.float32),
             "b": jnp.asarray(rng.normal(size=(4,)), jnp.float32)}
            for _ in range(P)]


def flat(tree) -> np.ndarray:
    return np.concatenate([np.asarray(l, np.float32).ravel()
                           for l in jax.tree.leaves(tree)])


BASE = {"w": jnp.zeros((3, 2), jnp.float32), "b": jnp.zeros(4, jnp.float32)}


@settings(max_examples=25, deadline=None)
@given(members_st, seed_st, st.sampled_from(agg.ROBUST_METHODS))
def test_permutation_invariance(P, seed, method):
    ms = make_members(P, seed)
    perm = np.random.default_rng(seed + 1).permutation(P)
    a = agg.robust_aggregate(ms, method, base=BASE)
    b = agg.robust_aggregate([ms[i] for i in perm], method, base=BASE)
    np.testing.assert_allclose(flat(a), flat(b), rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(members_st, seed_st, st.sampled_from(agg.ROBUST_METHODS))
def test_identical_updates_pass_through(P, seed, method):
    one = make_members(1, seed)[0]
    out = agg.robust_aggregate([one] * P, method, base=BASE)
    np.testing.assert_allclose(flat(out), flat(one), rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(members_st, seed_st)
def test_trim_zero_equals_uniform_mean(P, seed):
    ms = make_members(P, seed)
    out = agg.robust_aggregate(ms, "trimmed_mean", trim_frac=0.0)
    mean = np.mean([flat(m) for m in ms], axis=0)
    np.testing.assert_allclose(flat(out), mean, rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=5, max_value=11), seed_st,
       st.sampled_from(("trimmed_mean", "median", "krum")))
def test_breakdown_bound_with_f_corrupt(P, seed, method):
    """f = floor((P-1)/4) corrupt members at huge magnitude cannot pull the
    fold outside the honest coordinate-wise envelope (trimmed/median) or
    honest selection (krum with a matched f budget)."""
    f = max(1, (P - 1) // 4)
    ms = make_members(P, seed)
    big = 1e6
    for i in range(f):
        ms[i] = jax.tree.map(lambda l: l * 0 + big, ms[i])
    honest = np.stack([flat(m) for m in ms[f:]])
    out = flat(agg.robust_aggregate(
        ms, method, trim_frac=f / P, krum_f=f))
    lo, hi = honest.min(axis=0), honest.max(axis=0)
    assert np.all(out >= lo - 1e-4) and np.all(out <= hi + 1e-4)


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=3, max_value=9), seed_st,
       st.floats(min_value=1.0, max_value=4.0))
def test_norm_clip_bounds_the_fold(P, seed, clip_mult):
    """After clipping, every member's delta norm is <= clip_mult x the
    median norm, so the weighted mean's delta norm is too."""
    ms = make_members(P, seed)
    ms[0] = jax.tree.map(lambda l: l * 1e5, ms[0])  # one runaway update
    norms = [agg.delta_norm(m, BASE) for m in ms]
    thr = clip_mult * float(np.median(norms))
    out = agg.robust_aggregate(ms, "norm_clip", base=BASE,
                               clip_mult=clip_mult)
    assert agg.delta_norm(out, BASE) <= thr + 1e-3


def test_trim_k_clamps():
    assert agg.trim_k(5, 0.2) == 1
    assert agg.trim_k(5, 0.5) == 2      # clamped: >= 1 survivor
    assert agg.trim_k(3, 0.9) == 1
    assert agg.trim_k(10, 0.0) == 0


def test_krum_excludes_far_outliers():
    rng = np.random.default_rng(0)
    ms = [{"w": jnp.asarray(rng.normal(size=(4,)), jnp.float32)}
          for _ in range(6)]
    ms.append({"w": jnp.full((4,), 1e6, jnp.float32)})
    sel = agg.krum_select(agg._stack_trees(ms), f=1)
    assert 6 not in sel                 # the outlier is never selected


def test_unknown_method_raises():
    with pytest.raises(ValueError):
        agg.robust_aggregate(make_members(3, 0), "no_such_method")
