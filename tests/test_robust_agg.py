"""Property tests for the Byzantine-robust aggregators (core/aggregation.py).

  * permutation invariance: member order never changes the fold;
  * degenerate agreement: identical updates come back unchanged, and
    trim_frac=0 trimmed mean == the uniform mean;
  * breakdown bound: with f corrupt members at arbitrary magnitude and a
    matched trim/krum budget, the fold stays inside the honest members'
    coordinate-wise envelope (trimmed/median/krum) or norm ball (norm_clip).
Driven by hypothesis when installed, else the deterministic fallback shim.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback (tests/_hypothesis_compat.py)
    from _hypothesis_compat import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import aggregation as agg

members_st = st.integers(min_value=3, max_value=9)
seed_st = st.integers(min_value=0, max_value=2**31 - 1)


def make_members(P: int, seed: int):
    rng = np.random.default_rng(seed)
    return [{"w": jnp.asarray(rng.normal(size=(3, 2)), jnp.float32),
             "b": jnp.asarray(rng.normal(size=(4,)), jnp.float32)}
            for _ in range(P)]


def flat(tree) -> np.ndarray:
    return np.concatenate([np.asarray(l, np.float32).ravel()
                           for l in jax.tree.leaves(tree)])


BASE = {"w": jnp.zeros((3, 2), jnp.float32), "b": jnp.zeros(4, jnp.float32)}


@settings(max_examples=25, deadline=None)
@given(members_st, seed_st, st.sampled_from(agg.ROBUST_METHODS))
def test_permutation_invariance(P, seed, method):
    ms = make_members(P, seed)
    perm = np.random.default_rng(seed + 1).permutation(P)
    a = agg.robust_aggregate(ms, method, base=BASE)
    b = agg.robust_aggregate([ms[i] for i in perm], method, base=BASE)
    np.testing.assert_allclose(flat(a), flat(b), rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(members_st, seed_st, st.sampled_from(agg.ROBUST_METHODS))
def test_identical_updates_pass_through(P, seed, method):
    one = make_members(1, seed)[0]
    out = agg.robust_aggregate([one] * P, method, base=BASE)
    np.testing.assert_allclose(flat(out), flat(one), rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(members_st, seed_st)
def test_trim_zero_equals_uniform_mean(P, seed):
    ms = make_members(P, seed)
    out = agg.robust_aggregate(ms, "trimmed_mean", trim_frac=0.0)
    mean = np.mean([flat(m) for m in ms], axis=0)
    np.testing.assert_allclose(flat(out), mean, rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=5, max_value=11), seed_st,
       st.sampled_from(("trimmed_mean", "median", "krum")))
def test_breakdown_bound_with_f_corrupt(P, seed, method):
    """f = floor((P-1)/4) corrupt members at huge magnitude cannot pull the
    fold outside the honest coordinate-wise envelope (trimmed/median) or
    honest selection (krum with a matched f budget)."""
    f = max(1, (P - 1) // 4)
    ms = make_members(P, seed)
    big = 1e6
    for i in range(f):
        ms[i] = jax.tree.map(lambda l: l * 0 + big, ms[i])
    honest = np.stack([flat(m) for m in ms[f:]])
    out = flat(agg.robust_aggregate(
        ms, method, trim_frac=f / P, krum_f=f))
    lo, hi = honest.min(axis=0), honest.max(axis=0)
    assert np.all(out >= lo - 1e-4) and np.all(out <= hi + 1e-4)


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=3, max_value=9), seed_st,
       st.floats(min_value=1.0, max_value=4.0))
def test_norm_clip_bounds_the_fold(P, seed, clip_mult):
    """After clipping, every member's delta norm is <= clip_mult x the
    median norm, so the weighted mean's delta norm is too."""
    ms = make_members(P, seed)
    ms[0] = jax.tree.map(lambda l: l * 1e5, ms[0])  # one runaway update
    norms = [agg.delta_norm(m, BASE) for m in ms]
    thr = clip_mult * float(np.median(norms))
    out = agg.robust_aggregate(ms, "norm_clip", base=BASE,
                               clip_mult=clip_mult)
    assert agg.delta_norm(out, BASE) <= thr + 1e-3


def test_trim_k_clamps():
    assert agg.trim_k(5, 0.2) == 1
    assert agg.trim_k(5, 0.5) == 2      # clamped: >= 1 survivor
    assert agg.trim_k(3, 0.9) == 1
    assert agg.trim_k(10, 0.0) == 0


def test_krum_excludes_far_outliers():
    rng = np.random.default_rng(0)
    ms = [{"w": jnp.asarray(rng.normal(size=(4,)), jnp.float32)}
          for _ in range(6)]
    ms.append({"w": jnp.full((4,), 1e6, jnp.float32)})
    sel = agg.krum_select(agg._stack_trees(ms), f=1)
    assert 6 not in sel                 # the outlier is never selected


def test_unknown_method_raises():
    with pytest.raises(ValueError):
        agg.robust_aggregate(make_members(3, 0), "no_such_method")


# ---- robust fold x compressed exchange -----------------------------------
# launch/train.py --robust-agg X --compress Y: the fold must see (and the
# quarantine gate must threshold) the DECOMPRESSED per-island deltas the
# wire actually carries, not full-precision local weights.

from repro.core import compression as comp
from repro.core.faults import finite_members


def _stacked(P, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(P, 6, 4)) * scale, jnp.float32),
            "b": jnp.asarray(rng.normal(size=(P, 8)) * scale, jnp.float32)}


@pytest.mark.parametrize("mode", ["q8", "topk", "q8_topk"])
def test_roundtrip_islands_keeps_honest_members_finite(mode):
    P = 3
    stacked, base = _stacked(P, 0), _stacked(P, 1, scale=0.0)
    out = comp.roundtrip_islands(stacked, base, mode=mode, k_frac=0.2)
    assert finite_members(out).all()
    assert jax.tree.structure(out) == jax.tree.structure(stacked)
    assert all(a.shape == b.shape for a, b in
               zip(jax.tree.leaves(out), jax.tree.leaves(stacked)))


def test_roundtrip_islands_q8_reconstruction_bounded():
    """Per-island q8 wire: reconstruction error <= one quant step
    (amax / 127) of that island's OWN delta -- islands never share block
    scales."""
    P = 4
    stacked, base = _stacked(P, 2), _stacked(P, 3)
    out = comp.roundtrip_islands(stacked, base, mode="q8")
    for i in range(P):
        for k in ("w", "b"):
            delta = np.asarray(stacked[k][i] - base[k][i])
            err = np.abs(np.asarray(out[k][i]) - np.asarray(stacked[k][i]))
            assert err.max() <= np.abs(delta).max() / 127.0 + 1e-6


def test_roundtrip_islands_payloads_are_independent():
    """Corrupting island 1 must not move island 0's reconstruction by one
    bit: payloads (top-k selection, block scales) never straddle
    islands."""
    P = 2
    stacked, base = _stacked(P, 4), _stacked(P, 5, scale=0.0)
    ref = comp.roundtrip_islands(stacked, base, mode="q8_topk", k_frac=0.3)
    hot = jax.tree.map(lambda l: l.at[1].mul(1e6), stacked)
    got = comp.roundtrip_islands(hot, base, mode="q8_topk", k_frac=0.3)
    np.testing.assert_array_equal(np.asarray(ref["w"][0]),
                                  np.asarray(got["w"][0]))
    np.testing.assert_array_equal(np.asarray(ref["b"][0]),
                                  np.asarray(got["b"][0]))


@pytest.mark.parametrize("mode", ["topk", "q8_topk"])
def test_quarantine_gate_thresholds_decompressed_deltas(mode):
    """An inf smuggled into one island's delta has the largest magnitude,
    so top-k KEEPS it: the post-roundtrip finite_members gate (what
    train.py re-ands into `ok`) flags exactly that island while honest
    islands -- including one with a huge-but-finite delta -- pass."""
    P = 3
    stacked, base = _stacked(P, 6), _stacked(P, 7, scale=0.0)
    stacked = jax.tree.map(lambda l: l, stacked)
    stacked["w"] = stacked["w"].at[1, 0, 0].set(jnp.inf)   # corrupt island 1
    stacked["b"] = stacked["b"].at[2].mul(1e4)             # big-but-honest
    out = comp.roundtrip_islands(stacked, base, mode=mode, k_frac=0.2)
    ok = finite_members(out)
    assert not ok[1]
    assert ok[0] and ok[2]


def test_robust_agg_with_compression_converges(capsys):
    """End-to-end smoke: --compress q8-topk --robust-agg trimmed_mean
    trains through real exchanges and the loss goes down (the tier-1
    convergence gate for the robust x compressed composition)."""
    from repro.launch import train
    train.main(["--arch", "granite-20b", "--smoke", "--steps", "12",
                "--islands", "2", "--local-steps", "2", "--batch", "4",
                "--seq", "32", "--compress", "q8-topk",
                "--robust-agg", "trimmed_mean", "--seed", "0"])
    lines = capsys.readouterr().out.splitlines()
    losses = [float(ln.split("loss=")[1].split()[0])
              for ln in lines if "loss=" in ln]
    assert len(losses) == 12
    # the exchange path actually ran (tagged robust+compressed)
    assert any("robust-exchange:trimmed_mean+q8-topk" in ln for ln in lines)
    assert losses[-1] < losses[0], losses
