"""Scenario engine tests: determinism, churn / straggler / drift /
participation semantics at small scale (tier-1), and the 10^5-worker
suite under explicit wall-clock bounds (`scale` marker, separate CI job).
"""
import time

import numpy as np
import pytest

from repro.core.scenarios import ScenarioConfig, ScenarioSim

BASE = dict(n_workers=256, cohort_size=8, participation=0.25, epochs=1,
            samples_per_worker=64, seed=7)


def records_tuple(result):
    return [(r.time, r.acc, r.round, r.n_selected, r.version)
            for r in result.records]


# -- determinism -----------------------------------------------------------

def test_sync_deterministic_records():
    cfg = ScenarioConfig(**BASE, churn_leave=0.05, churn_join=0.05,
                         straggler_frac=0.1, drift=0.4, dirichlet_alpha=0.5)
    r1 = ScenarioSim(cfg).run_sync(4)
    r2 = ScenarioSim(cfg).run_sync(4)
    assert records_tuple(r1) == records_tuple(r2)


def test_async_deterministic_records():
    cfg = ScenarioConfig(**BASE, churn_leave=0.05, churn_join=0.05,
                         straggler_frac=0.1, drift=0.4, dirichlet_alpha=0.5)
    r1 = ScenarioSim(cfg).run_async(16)
    r2 = ScenarioSim(cfg).run_async(16)
    assert records_tuple(r1) == records_tuple(r2)


# -- scenario semantics ----------------------------------------------------

def test_partial_participation_counts():
    cfg = ScenarioConfig(**BASE)
    r = ScenarioSim(cfg).run_sync(3)
    expect = int(round(0.25 * 256))
    assert all(rec.n_selected == expect for rec in r.records[1:])


def test_churn_shrinks_and_recovers_fleet():
    leave_only = ScenarioConfig(**{**BASE, "seed": 11}, churn_leave=0.3)
    sim = ScenarioSim(leave_only)
    r = sim.run_sync(5)
    n_sel = [rec.n_selected for rec in r.records[1:]]
    assert n_sel[-1] < n_sel[0]          # fleet bleeds out
    assert sim.alive.sum() < 256
    balanced = ScenarioConfig(**{**BASE, "seed": 11}, churn_leave=0.3,
                              churn_join=0.3)
    sim2 = ScenarioSim(balanced)
    sim2.run_sync(5)
    assert sim2.alive.sum() > sim.alive.sum()


def test_stragglers_stretch_round_time():
    fast = ScenarioSim(ScenarioConfig(**BASE)).run_sync(3)
    slow = ScenarioSim(ScenarioConfig(**BASE, straggler_frac=0.2,
                                      straggler_slow=10.0)).run_sync(3)
    assert slow.records[-1].time > 2 * fast.records[-1].time


def test_non_iid_drift_rotates_label_skew():
    cfg = ScenarioConfig(**BASE, dirichlet_alpha=0.3, drift=1.0)
    sim = ScenarioSim(cfg)
    _, y0 = sim.shard_for(3, 0)
    _, y5 = sim.shard_for(3, 5)
    h0 = np.bincount(y0, minlength=10) / len(y0)
    h5 = np.bincount(y5, minlength=10) / len(y5)
    # skewed (far from uniform) and drifting (distribution moved)
    assert np.abs(h0 - 0.1).max() > 0.1
    assert np.abs(h0 - h5).max() > 0.1
    # drift=1.0 is exactly a 5-class rotation after 5 rounds
    np.testing.assert_allclose(np.roll(
        np.bincount(sim.shard_for(3, 0)[1], minlength=10), 5),
        np.bincount(sim.shard_for(3, 5)[1], minlength=10), atol=len(y0) * 0.2)


def test_sync_learns_iid():
    cfg = ScenarioConfig(n_workers=256, cohort_size=16, participation=0.5,
                         epochs=2, samples_per_worker=128, seed=0)
    r = ScenarioSim(cfg).run_sync(10)
    assert r.best_acc > 0.5
    times = [rec.time for rec in r.records]
    assert all(b > a for a, b in zip(times, times[1:]))


def test_async_learns_iid():
    cfg = ScenarioConfig(n_workers=256, cohort_size=16, participation=0.5,
                         epochs=2, samples_per_worker=128, seed=0)
    r = ScenarioSim(cfg).run_async(120)
    assert r.best_acc > 0.35
    assert all(rec.n_selected <= 1 for rec in r.records[1:])


def test_fog_cells_match_single_cell():
    one = ScenarioSim(ScenarioConfig(**BASE, fog_cells=1)).run_sync(3)
    four = ScenarioSim(ScenarioConfig(**BASE, fog_cells=4)).run_sync(3)
    np.testing.assert_allclose([r.acc for r in one.records],
                               [r.acc for r in four.records], atol=1e-3)
    assert [r.time for r in one.records] == [r.time for r in four.records]


# -- the 10^5 suite (scale marker: separate CI job, wall-clock bounded) ----

SCALE = dict(n_workers=100_000, cohort_size=16, participation=0.05,
             churn_leave=0.02, churn_join=0.02, straggler_frac=0.05,
             straggler_slow=8.0, drift=0.3, dirichlet_alpha=0.5,
             epochs=1, samples_per_worker=64, seed=1)
SYNC_BOUND_S = 90.0
ASYNC_BOUND_S = 90.0


@pytest.mark.scale
def test_scale_sync_churn_straggler_noniid_under_bound():
    t0 = time.monotonic()
    sim = ScenarioSim(ScenarioConfig(**SCALE))
    r = sim.run_sync(5)
    wall = time.monotonic() - t0
    assert wall < SYNC_BOUND_S, f"10^5 sync scenario took {wall:.1f}s"
    # full population timing: ~5% of 10^5 selected each round
    assert all(3500 < rec.n_selected < 6500 for rec in r.records[1:])
    # stragglers set the barrier: round time >> fastest worker's time
    assert r.records[1].time > float(np.min(sim.t_one))
    assert r.best_acc > 0.1  # quality is live, not a stub
    times = [rec.time for rec in r.records]
    assert all(b > a for a, b in zip(times, times[1:]))


@pytest.mark.scale
def test_scale_async_churn_straggler_noniid_under_bound():
    t0 = time.monotonic()
    r = ScenarioSim(ScenarioConfig(**SCALE)).run_async(64)
    wall = time.monotonic() - t0
    assert wall < ASYNC_BOUND_S, f"10^5 async scenario took {wall:.1f}s"
    assert len(r.records) == 65
    assert r.best_acc > 0.1
    times = [rec.time for rec in r.records]
    assert all(b >= a for a, b in zip(times, times[1:]))


@pytest.mark.scale
def test_scale_deterministic():
    cfg = ScenarioConfig(**{**SCALE, "seed": 2})
    r1 = ScenarioSim(cfg).run_sync(3)
    r2 = ScenarioSim(cfg).run_sync(3)
    assert records_tuple(r1) == records_tuple(r2)
