"""Unit + property tests for the worker-selection policies (paper SSIII-D)."""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback (tests/_hypothesis_compat.py)
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import selection as sel
from repro.core.cost_model import WorkerStats

settings.register_profile("fast", max_examples=25, deadline=None)
settings.load_profile("fast")


def stats_of(t_ones, t_tx=0.5, n_data=10):
    return {i: WorkerStats(wid=i, t_one=t, t_transmit=t_tx, n_data=n_data)
            for i, t in enumerate(t_ones)}


# ---------------- Algorithm 1 ----------------

def test_rminmax_excludes_slow_workers():
    st_ = sel.RMinRMaxState(rmin=2, rmax=4)
    s = stats_of([1.0, 1.0, 10.0])  # fastest max-time = 4.5; slow min = 20.5
    assert sel.rmin_rmax_select(s, st_) == [0, 1]


def test_rminmax_includes_all_when_diverged():
    """The paper's pathology: rmin->1, rmax huge => everyone selected."""
    st_ = sel.RMinRMaxState(rmin=1, rmax=1000)
    s = stats_of([1.0, 5.0, 50.0])
    assert sel.rmin_rmax_select(s, st_) == [0, 1, 2]


def test_rminmax_update_direction():
    st0 = sel.RMinRMaxState(rmin=4, rmax=8, acc_prev=0.2)
    st1 = sel.rmin_rmax_update(st0, acc_now=0.5)  # accuracy grew
    assert st1.rmin < st0.rmin and st1.rmax > st0.rmax
    st2 = sel.rmin_rmax_update(
        sel.RMinRMaxState(rmin=4, rmax=8, acc_prev=0.5), acc_now=0.3)
    assert st2.rmin > 4 and st2.rmax <= 8  # accuracy fell: tighten


@given(st.lists(st.floats(0.1, 20.0), min_size=2, max_size=10))
def test_rminmax_always_selects_fastest(t_ones):
    st_ = sel.RMinRMaxState(rmin=2, rmax=4)
    s = stats_of(t_ones)
    chosen = sel.rmin_rmax_select(s, st_)
    fastest = min(s, key=lambda w: s[w].t_one * st_.rmax + s[w].t_transmit)
    assert fastest in chosen


@given(st.floats(0.0, 1.0), st.floats(0.0, 1.0))
def test_rminmax_update_keeps_invariants(a0, a1):
    st_ = sel.RMinRMaxState(rmin=3, rmax=6, acc_prev=a0)
    new = sel.rmin_rmax_update(st_, a1)
    assert new.rmin >= 1.0
    assert new.rmax >= new.rmin


# ---------------- Algorithm 2 ----------------

def test_time_based_cold_start_selects_none():
    st_ = sel.TimeBasedState(T=0.0, r=2)
    assert sel.time_based_select(stats_of([1.0, 2.0]), st_) == []


def test_time_based_selects_within_budget():
    st_ = sel.TimeBasedState(T=3.0, r=2)
    s = stats_of([1.0, 1.2, 5.0])  # totals: 2.5, 2.9, 10.5
    assert sel.time_based_select(s, st_) == [0, 1]


def test_time_based_update_admits_cheapest_unselected():
    s = stats_of([1.0, 2.0, 5.0])
    st_ = sel.TimeBasedState(T=2.6, r=2, A=0.01, acc_prev=0.50)
    # stalled accuracy: T grows to the cheapest unselected total (2*2+0.5)
    new = sel.time_based_update(s, st_, acc_now=0.505)
    assert np.isclose(new.T, 4.5)
    assert sel.time_based_select(s, new) == [0, 1]


def test_time_based_no_update_when_improving():
    s = stats_of([1.0, 2.0])
    st_ = sel.TimeBasedState(T=2.6, r=2, A=0.01, acc_prev=0.3)
    new = sel.time_based_update(s, st_, acc_now=0.5)
    assert new.T == 2.6


@given(st.lists(st.floats(0.1, 10.0), min_size=2, max_size=10),
       st.floats(0.0, 30.0), st.floats(0.0, 30.0))
def test_time_based_monotone_in_T(t_ones, T1, T2):
    """Larger budgets can only ADD workers (selection monotonicity)."""
    s = stats_of(t_ones)
    lo, hi = sorted([T1, T2])
    sel_lo = set(sel.time_based_select(s, sel.TimeBasedState(T=lo, r=2)))
    sel_hi = set(sel.time_based_select(s, sel.TimeBasedState(T=hi, r=2)))
    assert sel_lo <= sel_hi


# ---------------- baselines ----------------

def test_random_selection_deterministic_given_rng():
    s = stats_of([1, 2, 3, 4, 5])
    a = sel.select_random(s, 3, np.random.default_rng(7))
    b = sel.select_random(s, 3, np.random.default_rng(7))
    assert a == b and len(a) == 3


def test_select_fastest():
    s = stats_of([3.0, 1.0, 2.0])
    assert sel.select_fastest(s, 2) == [1, 2]
