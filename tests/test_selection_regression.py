"""Regression tests for the selection update rules (paper SSIII-D).

Guards two documented pathologies:
  * rmin/rmax divergence -- the update must keep 1 <= rmin <= rmax under
    ANY accuracy sequence (the paper's Eq. 1/2 as printed diverge; see
    selection.py's module docstring and benchmarks/fig15-16);
  * the time-based oscillation bug -- T must be MONOTONE non-decreasing
    even when measured worker times drift upward between rounds (without
    the max() in time_based_update the pool oscillates at 3-4 workers).
"""
import dataclasses

import numpy as np

from repro.core import selection as sel
from repro.core.cost_model import WorkerStats


def _stats(t_ones, t_tx=0.5):
    return {i: WorkerStats(wid=i, t_one=float(t), t_transmit=t_tx, n_data=10)
            for i, t in enumerate(t_ones)}


def _adversarial_accuracy_sequences():
    rng = np.random.default_rng(42)
    yield [0.0, 1.0] * 25                      # hard oscillation
    yield [1.0, 0.0] * 25
    yield list(np.linspace(0.0, 1.0, 50))      # steady growth
    yield list(np.linspace(1.0, 0.0, 50))      # steady collapse
    yield [0.5] * 50                           # stall
    yield list(rng.uniform(0.0, 1.0, 200))     # noise
    yield [0.0] * 10 + [1.0] * 10 + [0.0] * 10


def test_rmin_rmax_update_invariants_under_adversarial_sequences():
    for seq in _adversarial_accuracy_sequences():
        state = sel.RMinRMaxState(rmin=3.0, rmax=6.0)
        for acc in seq:
            state = sel.rmin_rmax_update(state, acc)
            assert state.rmin >= 1.0, (seq[:5], state)
            assert state.rmax >= state.rmin, (seq[:5], state)


def test_rmin_rmax_update_survives_extreme_starts():
    for rmin, rmax in [(1.0, 1.0), (1.0, 1e6), (50.0, 50.0)]:
        state = sel.RMinRMaxState(rmin=rmin, rmax=rmax)
        for acc in [0.0, 1.0, 0.0, 1.0, 0.5]:
            state = sel.rmin_rmax_update(state, acc)
            assert 1.0 <= state.rmin <= state.rmax


def test_time_based_T_monotone_under_drifting_measurements():
    rng = np.random.default_rng(7)
    stats = _stats([1.0, 2.0, 5.0, 9.0])
    state = sel.TimeBasedState(T=0.0, r=2, A=0.01)
    prev_T = state.T
    for step in range(100):
        # measured times drift: slow workers get slower, fast ones jitter
        for w, s in stats.items():
            s.t_one = max(0.05, s.t_one * float(rng.uniform(0.9, 1.2)))
        acc = float(rng.uniform(0.0, 0.01))    # mostly stalled accuracy
        state = sel.time_based_update(stats, state, acc)
        assert state.T >= prev_T, (step, prev_T, state.T)
        prev_T = state.T


def test_time_based_T_monotone_even_when_accuracy_improves():
    stats = _stats([1.0, 2.0])
    state = sel.TimeBasedState(T=3.0, r=2, A=0.005, acc_prev=0.1)
    for acc in [0.2, 0.3, 0.31, 0.311, 0.9]:
        new = sel.time_based_update(stats, state, acc)
        assert new.T >= state.T
        state = new


def test_time_based_admission_grows_pool_not_shrinks():
    """Once a worker fits in T it keeps fitting (fixed measurements)."""
    stats = _stats([1.0, 2.0, 4.0, 8.0])
    state = sel.TimeBasedState(T=0.0, r=2, A=1.0)  # always "stalled"
    sizes = []
    for _ in range(10):
        state = sel.time_based_update(stats, state, acc_now=0.0)
        sizes.append(len(sel.time_based_select(stats, state)))
    assert sizes == sorted(sizes)
    assert sizes[-1] == len(stats)
