"""Continuous batching: ragged slots must reproduce solo-serving outputs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.serve_loop import Request, ServeLoop
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import build_model


def solo_generate(model, params, prompt, max_new):
    """Reference: serve one request alone through prefill+decode."""
    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model))
    toks = jnp.asarray(np.asarray(prompt, np.int32)[None])
    nxt, cache = prefill(params, {"tokens": toks})
    out = [int(nxt[0])]
    pos = len(prompt)
    while len(out) < max_new:
        nxt, cache = decode(params, {
            "tokens": nxt[:, None].astype(jnp.int32),
            "positions": jnp.full((1, 1), pos, jnp.int32)}, cache)
        out.append(int(nxt[0]))
        pos += 1
    return out


@pytest.mark.parametrize("arch", ["granite-20b", "falcon-mamba-7b"])
def test_continuous_batching_matches_solo(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (12, 7, 19)]
    want = [solo_generate(model, params, p, 6) for p in prompts]

    loop = ServeLoop(model, params, max_batch=2, max_len=128)
    reqs = [Request(rid=i, prompt=p, max_new=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        loop.submit(r)  # 3 requests > 2 slots: the third joins mid-flight
    done = loop.run_until_drained()
    assert len(done) == 3
    got = {r.rid: r.out for r in done}
    for i in range(3):
        assert got[i] == want[i], (i, got[i], want[i])


def test_slots_recycled_and_queue_drains():
    cfg = get_smoke_config("granite-20b")
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    loop = ServeLoop(model, params, max_batch=2, max_len=64)
    rng = np.random.default_rng(1)
    for i in range(5):
        loop.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, 8).astype(np.int32), max_new=3))
    done = loop.run_until_drained()
    assert sorted(r.rid for r in done) == [0, 1, 2, 3, 4]
    assert all(len(r.out) == 3 for r in done)
    assert sorted(loop.free) == [0, 1]
