"""Continuous batching: ragged slots must reproduce solo-serving outputs,
and the block-table paged cache must reproduce the contiguous cache
token-for-token (incl. mid-flight joins, slot reuse, prefix sharing and
preemption)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.serve_loop import PagedServeLoop, Request, ServeLoop
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import build_model


def solo_generate(model, params, prompt, max_new):
    """Reference: serve one request alone through prefill+decode."""
    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model))
    toks = jnp.asarray(np.asarray(prompt, np.int32)[None])
    nxt, cache = prefill(params, {"tokens": toks})
    out = [int(nxt[0])]
    pos = len(prompt)
    while len(out) < max_new:
        nxt, cache = decode(params, {
            "tokens": nxt[:, None].astype(jnp.int32),
            "positions": jnp.full((1, 1), pos, jnp.int32)}, cache)
        out.append(int(nxt[0]))
        pos += 1
    return out


@pytest.mark.parametrize("arch", ["granite-20b", "falcon-mamba-7b"])
def test_continuous_batching_matches_solo(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (12, 7, 19)]
    want = [solo_generate(model, params, p, 6) for p in prompts]

    loop = ServeLoop(model, params, max_batch=2, max_len=128)
    reqs = [Request(rid=i, prompt=p, max_new=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        loop.submit(r)  # 3 requests > 2 slots: the third joins mid-flight
    done = loop.run_until_drained()
    assert len(done) == 3
    got = {r.rid: r.out for r in done}
    for i in range(3):
        assert got[i] == want[i], (i, got[i], want[i])


def test_slots_recycled_and_queue_drains():
    cfg = get_smoke_config("granite-20b")
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    loop = ServeLoop(model, params, max_batch=2, max_len=64)
    rng = np.random.default_rng(1)
    for i in range(5):
        loop.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, 8).astype(np.int32), max_new=3))
    done = loop.run_until_drained()
    assert sorted(r.rid for r in done) == [0, 1, 2, 3, 4]
    assert all(len(r.out) == 3 for r in done)
    assert sorted(loop.free) == [0, 1]


# -- block-table paged cache ------------------------------------------------

def _drain(loop, prompts, max_new=6):
    for i, p in enumerate(prompts):
        loop.submit(Request(rid=i, prompt=p, max_new=max_new))
    done = loop.run_until_drained()
    assert len(done) == len(prompts)
    return {r.rid: r.out for r in done}


def test_paged_matches_contiguous_mid_flight_joins():
    """5 requests through 2 slots: the paged path (chunked+bucketed
    prefill, paged decode, slot reuse after eviction) must emit exactly
    the contiguous path's greedy token streams."""
    cfg = get_smoke_config("granite-20b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (12, 7, 19, 33, 5)]
    want = _drain(ServeLoop(model, params, max_batch=2, max_len=128),
                  prompts)
    ploop = PagedServeLoop(model, params, max_batch=2, num_blocks=32,
                           block_size=8, chunk=16)
    got = _drain(ploop, prompts)
    assert got == want
    ploop.alloc.check_invariants()
    assert not ploop.alloc.tables          # everything released
    assert ploop.alloc.n_free() == 32


def test_paged_prefix_sharing_is_token_identical():
    """Two prompts with a long common prefix: the second must re-use the
    first's full prefix blocks (no recompute) and still match the
    contiguous outputs exactly."""
    cfg = get_smoke_config("granite-20b")
    model = build_model(cfg)
    params = model.init(jax.random.key(3))
    rng = np.random.default_rng(3)
    base = rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
    prompts = [np.concatenate([base, rng.integers(0, cfg.vocab_size, k)
                               .astype(np.int32)]) for k in (5, 3, 9)]
    want = _drain(ServeLoop(model, params, max_batch=3, max_len=128),
                  prompts, max_new=5)
    ploop = PagedServeLoop(model, params, max_batch=3, num_blocks=32,
                           block_size=8, chunk=16)
    got = _drain(ploop, prompts, max_new=5)
    assert got == want
    # 24-token prefix = 3 full blocks, shared by requests 1 and 2
    assert ploop.alloc.stats["shared_blocks"] >= 6


def test_paged_preemption_requeues_exactly():
    """A pool too small for all admitted sequences forces preemption; the
    requeued request must still produce the exact greedy stream."""
    cfg = get_smoke_config("granite-20b")
    model = build_model(cfg)
    params = model.init(jax.random.key(4))
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (21, 23, 22)]
    want = _drain(ServeLoop(model, params, max_batch=3, max_len=128),
                  prompts, max_new=16)
    # 9 blocks x 8 = 72 positions for 3 x (>=21+16) = 111+ needed at once
    ploop = PagedServeLoop(model, params, max_batch=3, num_blocks=9,
                           block_size=8, chunk=16)
    got = _drain(ploop, prompts, max_new=16)
    assert got == want
    assert ploop.preemptions >= 1
    ploop.alloc.check_invariants()


def test_paged_rejects_stateful_families():
    cfg = get_smoke_config("falcon-mamba-7b")
    model = build_model(cfg)
    with pytest.raises(AssertionError, match="paged"):
        PagedServeLoop(model, model.init(jax.random.key(0)))


# -- host/device length bookkeeping ----------------------------------------

def test_lengths_dtype_matches_device_positions():
    """Regression: ServeLoop.lengths was np.int64 while `_next`/positions
    are int32 -- the implicit cast silently wraps past 2^31.  Both loops
    must keep host lengths in int32, and values near the boundary must
    round-trip exactly into the positions array fed to decode."""
    cfg = get_smoke_config("granite-20b")
    model = build_model(cfg)
    params = model.init(jax.random.key(5))
    loop = ServeLoop(model, params, max_batch=2, max_len=32)
    ploop = PagedServeLoop(model, params, max_batch=2, num_blocks=8,
                           block_size=8)
    for lo in (loop, ploop):
        assert lo.lengths.dtype == np.int32
        assert lo._next.dtype == jnp.int32
    big = 2**31 - 2              # one decode step of headroom left
    loop.lengths[0] = big
    positions = jnp.asarray(loop.lengths.reshape(loop.B, 1), jnp.int32)
    assert positions.dtype == jnp.int32
    assert int(positions[0, 0]) == big, "host->device length must be exact"
    # the int64 host array used to make this silently disagree:
    skewed = np.zeros(2, np.int64)
    skewed[0] = 2**31 + 5        # would wrap negative through int32
    assert int(skewed.astype(np.int32)[0]) != skewed[0]


# -- spec'd caches through the continuous loop -----------------------------

def test_serve_loop_ring_cache_token_identical():
    """ServeLoop(cache_spec="ring:4/bf16") rebuilds the model around the
    spec'd cache (params untouched) and reproduces the baseline stream
    token-for-token -- the CacheSpec contract holding through slot reuse
    and mid-flight joins, not just single-request decode."""
    cfg = get_smoke_config("granite-20b")
    model = build_model(cfg)
    params = model.init(jax.random.key(9))
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (12, 7, 19)]

    def drain(spec):
        loop = ServeLoop(model, params, max_batch=2, max_len=128,
                         cache_spec=spec)
        if spec:
            assert loop.model.cfg.cache_spec == spec
        for i, p in enumerate(prompts):
            loop.submit(Request(rid=i, prompt=p, max_new=6))
        return {r.rid: r.out for r in loop.run_until_drained()}

    assert drain("ring:4/bf16") == drain(None)
