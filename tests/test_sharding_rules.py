"""Logical-axis sharding rule resolution (pure metadata, no lowering)."""
import os

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (DEFAULT_RULES, ISLAND_RULES, abstract_mesh,
                                 logical_to_mesh_spec)


def fake_mesh(shape=(2, 4, 8), axes=("pod", "data", "model")):
    # AbstractMesh carries only names/sizes -- perfect for rule tests
    # (abstract_mesh papers over the ctor signature change across jax vers)
    return abstract_mesh(shape, axes)


def test_divisible_first_match():
    spec = logical_to_mesh_spec(("embed", "ffn"), (16, 64), fake_mesh())
    assert spec == P("data", "model")


def test_indivisible_falls_back_to_none():
    spec = logical_to_mesh_spec(("heads",), (6,), fake_mesh())  # 6 % 8 != 0
    assert spec == P(None)


def test_vocab_prefers_model_then_data():
    mesh = fake_mesh()
    assert logical_to_mesh_spec(("vocab",), (64,), mesh) == P("model")
    # 12 divides data(4) but not model(8)
    assert logical_to_mesh_spec(("vocab",), (12,), mesh) == P("data")


def test_stacked_batch_uses_all_fitting_axes():
    spec = logical_to_mesh_spec(("batch", None), (8, 5), fake_mesh(),
                                DEFAULT_RULES)
    assert spec == P(("pod", "data"), None)


def test_island_rules_batch_excludes_pod():
    spec = logical_to_mesh_spec(("batch", None), (8, 5), fake_mesh(),
                                ISLAND_RULES)
    assert spec == P("data", None)


def test_axis_used_once_per_tensor():
    # both dims want "model": "heads" wins (priority), "ffn" falls back
    spec = logical_to_mesh_spec(("ffn", "heads"), (64, 64), fake_mesh())
    assert spec == P(None, "model")
    # without a priority dim, first position wins
    spec = logical_to_mesh_spec(("ffn", "expert_ffn"), (64, 64), fake_mesh())
    assert spec == P("model", None)


def test_explicit_mesh_axis_tuple():
    spec = logical_to_mesh_spec(((("data", "model")), None), (32, 3),
                                fake_mesh())
    assert spec == P(("data", "model"), None)


def test_island_axis_maps_to_pod():
    spec = logical_to_mesh_spec(("island", "embed"), (2, 16), fake_mesh())
    assert spec == P("pod", "data")


def test_no_mesh_axis_absent():
    mesh = fake_mesh((4, 8), ("data", "model"))
    spec = logical_to_mesh_spec(("island", "embed"), (2, 16), mesh)
    assert spec == P(None, "data")
