"""Edge cases: SimResult accessors and mixing-matrix normalisation
invariants (`selection_mixing` / `async_mixing`)."""
import numpy as np

from repro.core import federated
from repro.core.events import SimRecord, SimResult

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback (tests/_hypothesis_compat.py)
    from _hypothesis_compat import given, settings, strategies as st

settings.register_profile("fast", max_examples=20, deadline=None)
settings.load_profile("fast")


# -- SimResult -------------------------------------------------------------

def test_simresult_empty_records():
    r = SimResult([])
    assert r.time_to_accuracy(0.5) == float("inf")
    assert r.best_acc == 0.0
    t, a = r.as_arrays()
    assert t.size == 0 and a.size == 0


def test_simresult_target_never_reached():
    recs = [SimRecord(float(i), 0.1 * i, i, 1, i) for i in range(4)]
    r = SimResult(recs)
    assert r.time_to_accuracy(0.99) == float("inf")
    assert abs(r.best_acc - 0.3) < 1e-12


def test_simresult_target_reached_at_first_crossing():
    recs = [SimRecord(0.0, 0.0, 0, 0, 0), SimRecord(1.5, 0.6, 1, 2, 1),
            SimRecord(2.5, 0.4, 2, 2, 2), SimRecord(3.5, 0.8, 3, 2, 3)]
    r = SimResult(recs)
    assert r.time_to_accuracy(0.5) == 1.5       # first crossing, not best
    assert r.time_to_accuracy(0.7) == 3.5
    assert r.best_acc == 0.8


# -- selection_mixing ------------------------------------------------------

@given(st.integers(2, 12), st.integers(0, 2**31 - 1))
def test_selection_mixing_rows_normalised(P, seed):
    rng = np.random.default_rng(seed)
    weights = rng.uniform(0.0, 3.0, P)
    selected = (rng.random(P) < 0.6).astype(float)
    M = federated.selection_mixing(weights, selected)
    np.testing.assert_allclose(M.sum(axis=1), 1.0, atol=1e-9)
    assert np.all(M >= 0)
    # unselected islands contribute nothing but still receive the mix
    if (weights * selected).sum() > 0:
        for j in np.flatnonzero((weights * selected) == 0):
            assert np.all(M[:, j] == 0.0)


def test_selection_mixing_nobody_selected_is_identity():
    M = federated.selection_mixing(np.ones(4), np.zeros(4))
    np.testing.assert_allclose(M, np.eye(4))


def test_selection_mixing_weight_proportionality():
    M = federated.selection_mixing(np.array([1.0, 3.0]), np.ones(2))
    np.testing.assert_allclose(M, [[0.25, 0.75], [0.25, 0.75]])


# -- async_mixing ----------------------------------------------------------

@given(st.integers(2, 12), st.integers(0, 2**31 - 1))
def test_async_mixing_rows_normalised(P, seed):
    rng = np.random.default_rng(seed)
    alphas = rng.uniform(0.0, 1.0, P)
    contributors = rng.uniform(0.0, 2.0, P)
    contributors[int(rng.integers(P))] = 1.0    # at least one contributor
    M = federated.async_mixing(alphas, contributors)
    np.testing.assert_allclose(M.sum(axis=1), 1.0, atol=1e-9)
    assert np.all(M >= -1e-12)


def test_async_mixing_zero_alpha_keeps_island_fixed():
    M = federated.async_mixing(np.array([0.0, 0.5]), np.array([0.0, 1.0]))
    np.testing.assert_allclose(M[0], [1.0, 0.0])   # alpha=0: row = identity
    np.testing.assert_allclose(M[1], [0.0, 1.0])   # full take of contributor
