"""End-to-end system tests: federated LM training on a 1-device mesh with
checkpoint/restart -- the full production path at CPU scale."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.core import federated as fed
from repro.data.synthetic import batch_token_stream, make_token_stream
from repro.launch.steps import (make_fl_aggregate, make_train_step,
                                make_prefill_step, make_decode_step)
from repro.models import build_model
from repro.optim import adamw


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("granite-20b")
    model = build_model(cfg)
    opt = adamw(3e-3)
    params = model.init(jax.random.key(0))
    opt_state = opt.init(params)
    stream = make_token_stream(cfg.vocab_size, 200_000, seed=0)
    return cfg, model, opt, params, opt_state, stream


def test_fl_islands_train_and_converge(setup):
    """2 virtual islands train on disjoint streams; sync exchange every 4
    steps; loss decreases and islands agree after each exchange."""
    cfg, model, opt, params, opt_state, stream = setup
    P = 2
    step = jax.jit(make_train_step(model, opt))
    agg = jax.jit(make_fl_aggregate())
    island_params = [params, jax.tree.map(lambda x: x + 0, params)]
    island_opt = [opt_state, jax.tree.map(lambda x: x + 0, opt_state)]
    M = jnp.asarray(fed.selection_mixing(np.full(P, 1 / P), np.ones(P)),
                    jnp.float32)
    losses = []
    for s in range(12):
        for i in range(P):
            x, y = batch_token_stream(stream, 4, 32, step=s * P + i + 1000 * i)
            island_params[i], island_opt[i], m = step(
                island_params[i], island_opt[i],
                {"tokens": jnp.asarray(x), "labels": jnp.asarray(y)})
            if i == 0:
                losses.append(float(m["loss"]))
        if (s + 1) % 4 == 0:
            stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *island_params)
            mixed = agg(stacked, M)
            island_params = [jax.tree.map(lambda l: l[i], mixed)
                             for i in range(P)]
    # consensus after final exchange
    for a, b in zip(jax.tree.leaves(island_params[0]),
                    jax.tree.leaves(island_params[1])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)
    assert np.mean(losses[-4:]) < np.mean(losses[:4])


def test_checkpoint_restart_bitwise_resume(setup, tmp_path):
    """Crash/restart: resuming from a checkpoint reproduces the exact same
    next step as the uninterrupted run (fault-tolerance contract)."""
    cfg, model, opt, params, opt_state, stream = setup
    step = jax.jit(make_train_step(model, opt))
    mgr = CheckpointManager(tmp_path, keep=2)

    def batch(s):
        x, y = batch_token_stream(stream, 4, 32, step=s)
        return {"tokens": jnp.asarray(x), "labels": jnp.asarray(y)}

    p, o = params, opt_state
    for s in range(3):
        p, o, _ = step(p, o, batch(s))
    mgr.save(3, params=p, opt_state=o, extra={"data_step": 3})
    p4, o4, m4 = step(p, o, batch(3))

    # simulated crash: fresh restore, repeat step 3
    rstep, rp, ro, extra = mgr.restore(params_like=params,
                                       opt_state_like=opt_state)
    assert rstep == 3 and extra["data_step"] == 3
    rp4, ro4, rm4 = step(jax.tree.map(jnp.asarray, rp),
                         jax.tree.map(jnp.asarray, ro), batch(3))
    assert float(rm4["loss"]) == pytest.approx(float(m4["loss"]), abs=1e-6)
    for a, b in zip(jax.tree.leaves(p4), jax.tree.leaves(rp4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_serve_path_prefill_decode(setup):
    cfg, model, opt, params, *_ = setup
    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model))
    B, T = 2, 16
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (B, T)), jnp.int32)
    nxt, cache = prefill(params, {"tokens": toks})
    assert nxt.shape == (B,)
    for i in range(3):
        nxt, cache = decode(params, {
            "tokens": nxt[:, None].astype(jnp.int32),
            "positions": jnp.full((B, 1), T + i, jnp.int32)}, cache)
    assert nxt.shape == (B,)
    assert not bool(jnp.isnan(nxt.astype(jnp.float32)).any())
