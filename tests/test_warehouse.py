"""Data warehouse: pointer addressing + one-time credentials (SSIII-B.1)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.warehouse import CredentialError, DataWarehouse, Pointer


def tree():
    return {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4)}}


def test_memory_roundtrip():
    wh = DataWarehouse()
    ptr = wh.put(tree())
    out = wh.get(ptr.uid)
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree()["a"]))


def test_disk_roundtrip(tmp_path):
    wh = DataWarehouse(root=tmp_path)
    ptr = wh.put(tree(), storage="disk")
    out = wh.get(ptr.uid)
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]), np.ones(4))
    assert (tmp_path / f"{ptr.uid}.npz").exists()


def test_credential_single_use():
    wh = DataWarehouse()
    ptr = wh.put(tree())
    tok = wh.issue_credential(ptr.uid)
    wh.fetch(tok)
    with pytest.raises(CredentialError):
        wh.fetch(tok)  # second use must fail (paper's one-time FTP login)


def test_credential_for_missing_uid():
    wh = DataWarehouse()
    with pytest.raises(KeyError):
        wh.issue_credential("nope")


def test_delete(tmp_path):
    wh = DataWarehouse(root=tmp_path)
    ptr = wh.put(tree(), storage="disk")
    wh.delete(ptr.uid)
    assert not wh.exists(ptr.uid)
    with pytest.raises(KeyError):
        wh.get(ptr.uid)


def test_pointer_identity():
    p = Pointer("10.0.0.1:9000", "abc")
    assert p.address == "10.0.0.1:9000" and p.uid == "abc"
